"""hloparse: execution-weighted HLO cost model vs exactly-known programs.

The whole roofline (EXPERIMENTS.md §Roofline) rests on this module, so the
flop accounting is validated against hand-computable programs, including the
while-loop trip-count multiplication that raw ``cost_analysis()`` misses.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.launch import hloparse

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_scan_body_multiplied_by_trip_count():
    def f_scan(x, w):
        def body(c, wi):
            return c @ wi, None
        c, _ = jax.lax.scan(body, x, w)
        return c

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    comp = jax.jit(f_scan).lower(x, w).compile()

    raw = comp.cost_analysis()
    if isinstance(raw, (list, tuple)):  # older jax returns [dict], newer dict
        raw = raw[0]
    raw = raw["flops"]
    s = hloparse.summarize(comp.as_text())
    expect = 8 * 2 * 128 * 256 * 256
    assert raw < expect / 4            # the undercount this module fixes
    assert abs(s["flops"] - expect) / expect < 0.01


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, wi):
            def inner(c2, _):
                return jnp.tanh(c2 @ wi), None
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        c, _ = jax.lax.scan(outer, x, w)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    s = hloparse.summarize(comp.as_text())
    expect = 3 * 4 * 2 * 64 * 64 * 64  # 12 executions of one matmul
    assert abs(s["flops"] - expect) / expect < 0.05


def test_unrolled_matches_scanned():
    """Same math scanned vs unrolled must give ~equal exec-weighted flops."""
    def f_scan(x, w):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, w)[0]

    def f_unroll(x, w):
        c = x
        for i in range(8):
            c = c @ w[i]
        return c

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    a = hloparse.summarize(jax.jit(f_scan).lower(x, w).compile().as_text())
    b = hloparse.summarize(jax.jit(f_unroll).lower(x, w).compile().as_text())
    assert abs(a["flops"] - b["flops"]) / b["flops"] < 0.01


def test_tuple_type_while_parses():
    """Regression: while-op tuple types embed /*index=N*/ comments that broke
    a regex-only parser (mult dropped to 1 silently)."""
    line = (
        "  %while.359 = (s32[], f32[16,4,7,256]{3,2,1,0}, "
        "/*index=5*/s32[256,1]{1,0}) while(%tuple.405), "
        "condition=%c, body=%b, "
        'backend_config={"known_trip_count":{"n":"28"}}'
    )
    parsed = hloparse._parse_op_line(line)
    assert parsed is not None
    name, type_str, opcode = parsed
    assert opcode == "while" and name == "while.359"
    assert hloparse.shape_bytes(type_str) == 4 + 16 * 4 * 7 * 256 * 4 + 256 * 4


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hloparse

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].mean()
    xs = jax.ShapeDtypeStruct((128, 256), jnp.float32,
                              sharding=NamedSharding(mesh, P("data", None)))
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, None, "model")))
    with mesh:
        comp = jax.jit(f).lower(xs, ws).compile()
    s = hloparse.summarize(comp.as_text())
    # per-device dot: (64,256)x(256,64) x 8 trips
    expect = 8 * 2 * 64 * 256 * 64
    assert abs(s["flops"] - expect) / expect < 0.02, s["flops"]
    # loop-carried all-gather of the x shard: f32[64,256] x 8 trips
    assert s["collective_bytes"]["all-gather"] == 8 * 64 * 256 * 4
    assert s["collective_counts"]["all-gather"] == 8
    print("SHARDED_OK")
""")


def test_sharded_collectives_exec_weighted():
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert "SHARDED_OK" in r.stdout, r.stderr[-2000:]
