"""Frontier-expansion kernel parity: Pallas interpret mode vs pure-jnp
reference, bit-exact, standalone and end-to-end through the traversal engine.

The kernel's contract is exact (integer scatter-min — no tolerances): the
tiled VMEM reduction must match the reference for any frontier/CSR input,
including the padding paths (lane-aligned widths, ragged edge counts), and
the whole BFS must produce identical levels/parents through either impl.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import SequentialGraph, WaitFreeGraph, bfs_parents, build_csr
from repro.core.workloads import sample_batch
from repro.kernels.frontier import NBR_INF, frontier_expand, frontier_expand_reference

KEY_SPACE = 24


@pytest.mark.parametrize(
    "S,C,Ce",
    [
        (1, 5, 3),        # degenerate: single source, tiny graph
        (3, 1, 1),        # single column
        (4, 65, 100),     # ragged everything
        (8, 128, 1000),   # lane-aligned C, ragged Ce (forces the extra block)
        (16, 257, 4096),  # multi-tile on both grid axes
        (5, 300, 2100),   # ragged S (padding rows) and Ce
    ],
)
def test_frontier_expand_parity_random(S, C, Ce):
    rng = np.random.default_rng(S * 1009 + C * 31 + Ce)
    frontier = jnp.asarray(rng.random((S, C)) < 0.3)
    src = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    ref = frontier_expand_reference(frontier, src, dst)
    ker = frontier_expand(frontier, src, dst, impl="kernel_interpret")
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_frontier_expand_empty_frontier_and_parent_semantics():
    rng = np.random.default_rng(7)
    C, Ce = 40, 200
    src = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    # empty frontier: nothing proposed anywhere
    empty = jnp.zeros((4, C), bool)
    out = frontier_expand(empty, src, dst, impl="kernel_interpret")
    assert (np.asarray(out) == NBR_INF).all()
    # full frontier: every column with an in-edge gets its min in-neighbor
    full = jnp.ones((2, C), bool)
    out = np.asarray(frontier_expand(full, src, dst, impl="kernel_interpret"))
    src_np, dst_np = np.asarray(src), np.asarray(dst)
    for d in range(C):
        preds = src_np[dst_np == d]
        expect = preds.min() if preds.size else NBR_INF
        assert out[0, d] == out[1, d] == expect


def test_frontier_expand_block_tilings_agree():
    """The reduction must be tiling-invariant: any (block_s, block_e) split
    yields the same bits (min is associative + commutative)."""
    from repro.kernels.frontier.kernel import frontier_expand as raw_kernel

    rng = np.random.default_rng(11)
    S, C, Ce = 8, 100, 600
    frontier = jnp.asarray(rng.random((S, C)) < 0.25)
    src = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    ref = np.asarray(frontier_expand_reference(frontier, src, dst))
    for block_s, block_e in [(1, 64), (4, 128), (8, 600), (8, 4096)]:
        got = raw_kernel(
            frontier, src, dst, block_s=block_s, block_e=block_e, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(got), ref)


def _churned_graph(seed: int):
    rng = np.random.default_rng(seed)
    g, o = WaitFreeGraph(256, 1024), SequentialGraph()
    for _ in range(2):
        ops, us, vs = sample_batch(rng, 160, "traversal", key_space=KEY_SPACE)
        got = g.apply(ops, us, vs)
        from repro.core import run_sequential

        exp, _ = run_sequential(ops, us, vs, graph=o)
        assert got.tolist() == exp
    return g, o, rng


@pytest.mark.parametrize("seed", range(4))
def test_bfs_through_kernel_matches_reference_and_oracle(seed):
    """End-to-end: the whole level loop through the interpret-mode kernel is
    bit-identical to the reference impl, and both match the oracle."""
    g, o, rng = _churned_graph(seed)
    csr = build_csr(g.state)
    keys = jnp.asarray(rng.integers(0, KEY_SPACE, 8).astype(np.int32))
    lv_ref, par_ref = bfs_parents(csr, keys, impl="reference")
    lv_ker, par_ker = bfs_parents(csr, keys, impl="kernel_interpret")
    np.testing.assert_array_equal(np.asarray(lv_ker), np.asarray(lv_ref))
    np.testing.assert_array_equal(np.asarray(par_ker), np.asarray(par_ref))

    v_key = np.asarray(csr.v_key)
    for s, row in zip(np.asarray(keys), np.asarray(lv_ker)):
        hit = np.nonzero(row >= 0)[0]
        assert {int(v_key[j]): int(row[j]) for j in hit} == o.bfs(int(s))
