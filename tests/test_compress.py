"""Int8 error-feedback gradient compression: numerics + real collectives."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compress import (
    compression_ratio, dequantize, ef_init, quantize,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0
    err = np.asarray(dequantize(quantize(x, scale), scale) - x)
    assert np.abs(err).max() <= float(scale) / 2 + 1e-7


def test_compression_ratio_near_4x():
    tree = {"a": jnp.zeros((1024, 1024)), "b": jnp.zeros((4096,))}
    r = compression_ratio(tree)
    assert 3.9 < r < 4.0


_COLLECTIVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.optim.compress import compressed_psum, ef_init

    # version compat: AxisType/jax.shard_map/jax.set_mesh are newer-jax names
    mesh_kwargs = {}
    if hasattr(jax.sharding, "AxisType"):
        mesh_kwargs["axis_types"] = (jax.sharding.AxisType.Explicit,)
    mesh = jax.make_mesh((4,), ("pod",), **mesh_kwargs)
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    def set_mesh(m):
        return jax.set_mesh(m) if hasattr(jax, "set_mesh") else m
    rng = np.random.default_rng(1)
    # per-pod gradients (4, n): the true mean is the uncompressed target
    g = rng.standard_normal((4, 256)).astype(np.float32)
    target = g.mean(axis=0)

    @partial(shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
             out_specs=(P("pod"), P("pod")))
    def step(gi, ei):
        out, new_e = compressed_psum(
            {"w": gi[0]}, {"w": ei[0]}, axis="pod"
        )
        return out["w"][None], new_e["w"][None]

    with set_mesh(mesh):
        e = jnp.zeros((4, 256), jnp.float32)
        out, e = step(jnp.asarray(g), e)
    out = np.asarray(out)
    # every pod got the identical compressed mean (determinism)
    assert np.all(out[0] == out[1]) and np.all(out[0] == out[3])
    # one-round quantization error is bounded by the scale
    scale = np.abs(g + 0).max() / 127.0
    assert np.abs(out[0] - target).max() < scale, (out[0] - target)

    # error feedback: averaging the SAME grads repeatedly converges to the
    # true mean (residuals re-enter), unlike plain repeated quantization
    with set_mesh(mesh):
        e = jnp.zeros((4, 256), jnp.float32)
        acc = np.zeros(256, np.float32)
        T = 64
        for _ in range(T):
            out, e = step(jnp.asarray(g), e)
            acc += np.asarray(out)[0]
    assert np.abs(acc / T - target).max() < 1e-3
    print("COMPRESS_OK")
""")


def test_compressed_psum_multidevice():
    r = subprocess.run(
        [sys.executable, "-c", _COLLECTIVE_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert "COMPRESS_OK" in r.stdout, (r.stderr[-2000:] or r.stdout[-500:])
