"""Oracle-equivalence tests for the batched traversal engine.

Every query form (pairwise reachability, BFS level maps, k-hop
neighborhoods) and the vectorized snapshot are validated exactly against the
sequential oracle, over deterministic constructions and ≥50 randomized
graphs — including vertex-deletion staleness and incarnation churn, the
Fig. 3 hazards that traversal must respect (a stale edge must never carry a
path)."""

import numpy as np
import pytest

from repro.core import (
    SequentialGraph,
    WaitFreeGraph,
    apply_delta,
    bfs_levels,
    build_csr,
    run_sequential,
)
from repro.core.types import (
    EMPTY_KEY,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
)
from repro.core.workloads import sample_batch, sample_query_pairs, sample_update_batch

KEY_SPACE = 24  # small key space: dense conflicts, real path structure


def _apply_both(g: WaitFreeGraph, oracle: SequentialGraph, ops, us, vs):
    got = g.apply(ops, us, vs)
    exp, _ = run_sequential(ops, us, vs, graph=oracle)
    assert got.tolist() == exp


def _chain(g: WaitFreeGraph, oracle: SequentialGraph, keys):
    n = len(keys)
    ops = np.concatenate([np.full(n, OP_ADD_VERTEX, np.int32),
                          np.full(n - 1, OP_ADD_EDGE, np.int32)])
    us = np.concatenate([np.asarray(keys, np.int32), np.asarray(keys[:-1], np.int32)])
    vs = np.concatenate([np.zeros(n, np.int32), np.asarray(keys[1:], np.int32)])
    _apply_both(g, oracle, ops, us, vs)


# ---------------------------------------------------------------------------
# deterministic semantics
# ---------------------------------------------------------------------------

def test_chain_levels_and_khop():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [10, 11, 12, 13, 14])
    assert g.bfs(10) == o.bfs(10) == {10: 0, 11: 1, 12: 2, 13: 3, 14: 4}
    assert g.bfs(14) == o.bfs(14) == {14: 0}  # directed: no back edges
    for k in range(5):
        assert g.khop(10, k) == o.khop(10, k)
    assert g.khop(10, 2) == {10, 11, 12}


def test_self_reachability_and_absent_endpoints():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2])
    for u, v in [(1, 1), (1, 2), (2, 1), (1, 99), (99, 1), (99, 99)]:
        assert g.reachable(u, v) == o.reachable(u, v)
    assert g.reachable(1, 1) is True     # empty path: u exists
    assert g.reachable(99, 99) is False  # absent vertex
    assert g.bfs(99) == {} == o.bfs(99)
    assert g.khop(99, 3) == set() == o.khop(99, 3)


def test_deleted_vertex_breaks_paths():
    """Removing a cut vertex must sever every path through it."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    assert g.reachable(1, 4) and o.reachable(1, 4)
    _apply_both(g, o, [OP_REMOVE_VERTEX], [3], [0])
    assert not g.reachable(1, 4) and not o.reachable(1, 4)
    assert g.reachable(1, 2) and o.reachable(1, 2)
    assert g.bfs(1) == o.bfs(1) == {1: 0, 2: 1}


def test_incarnation_churn_stale_edges_carry_no_path():
    """The Fig. 3 hazard, traversal edition: after remove+re-add of an
    endpoint, the stale edge's binding must not conduct reachability."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    _apply_both(g, o, [OP_REMOVE_VERTEX, OP_ADD_VERTEX], [2, 2], [0, 0])
    # 2 is live again, but edges 1->2 and 2->3 were bound to its old
    # incarnation: nothing is reachable through it.
    assert not g.reachable(1, 3) and not o.reachable(1, 3)
    assert not g.reachable(1, 2) and not o.reachable(1, 2)
    assert not g.reachable(2, 3) and not o.reachable(2, 3)
    assert g.bfs(1) == o.bfs(1) == {1: 0}
    # re-binding the edges at the new incarnation restores the path
    _apply_both(g, o, [OP_ADD_EDGE, OP_ADD_EDGE], [1, 2], [2, 3])
    assert g.reachable(1, 3) and o.reachable(1, 3)


def test_batch_queries_share_one_snapshot():
    """All queries in a batch linearize at the same batch boundary: pairs
    issued together see identical state, and the cached CSR is invalidated
    by the next apply."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    csr1 = g.traversal_csr()
    assert g.traversal_csr() is csr1  # cached between applies
    got = g.reachable([1, 1, 2], [2, 3, 3])
    assert got.tolist() == [True, True, True]
    _apply_both(g, o, [OP_REMOVE_VERTEX], [2], [0])
    assert g.traversal_csr() is not csr1  # invalidated
    assert g.reachable([1, 1, 2], [2, 3, 3]).tolist() == [False, False, False]


def test_readonly_batches_keep_cached_snapshot():
    """contains/NOP-only batches leave the abstract graph unchanged, so the
    cached CSR must survive them (queries interleaved with lookups stay
    amortized); any mutating op invalidates it."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    c0 = g.traversal_csr()
    assert g.contains_vertex(1) and g.contains_edge(1, 2)
    assert not g.contains_vertex(99)
    assert g.traversal_csr() is c0
    g.add_vertex(7)
    assert g.traversal_csr() is not c0


def test_csr_structure_invariants():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    _apply_both(g, o, [OP_ADD_EDGE, OP_ADD_EDGE], [1, 1], [3, 4])
    csr = build_csr(g.state)
    src = np.asarray(csr.src)
    dst = np.asarray(csr.dst)
    rs = np.asarray(csr.row_start)
    re = np.asarray(csr.row_end)
    cv = csr.v_capacity
    assert int(csr.n_live) == 4
    assert int(csr.n_edges) == 5
    # sorted by source slot, invalid lanes (== Cv) pushed to the tail
    assert (np.diff(src) >= 0).all()
    assert (src[int(csr.n_edges):] == cv).all() and (dst[int(csr.n_edges):] == cv).all()
    # row ranges partition the valid prefix and degrees sum to edge count
    assert int((re - rs).sum()) == int(csr.n_edges)
    v_key = np.asarray(csr.v_key)
    v_live = np.asarray(csr.v_live)
    deg = {1: 3, 2: 1, 3: 1, 4: 0}
    for j in range(cv):
        if v_live[j]:
            assert int(re[j] - rs[j]) == deg[int(v_key[j])]
            # every out-neighbor slot in the row holds a live vertex
            for t in dst[rs[j]:re[j]]:
                assert v_live[int(t)]


def test_bfs_levels_padding_lanes_are_inert():
    """EMPTY_KEY query lanes (batch padding) must return all -1 rows."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2])
    keys = np.asarray([1, EMPTY_KEY, 2, EMPTY_KEY], np.int32)
    lv = np.asarray(bfs_levels(build_csr(g.state), keys))
    assert (lv[1] == -1).all() and (lv[3] == -1).all()
    assert (lv[0] >= 0).sum() == 2 and (lv[2] >= 0).sum() == 1


def test_cyclic_graph_terminates_and_matches():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    _apply_both(g, o, [OP_ADD_EDGE], [3], [1])  # close the cycle
    assert g.reachable(3, 2) and o.reachable(3, 2)
    assert g.bfs(2) == o.bfs(2) == {2: 0, 3: 1, 1: 2}


def test_edge_free_snapshot_early_return():
    """n_edges == 0 snapshots skip the frontier loop entirely but still
    answer every query form correctly (sources are the whole answer)."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _apply_both(g, o, np.full(4, OP_ADD_VERTEX, np.int32),
                np.asarray([1, 2, 3, 4], np.int32), np.zeros(4, np.int32))
    assert int(build_csr(g.state).n_edges) == 0
    assert g.reachable([1, 1, 9], [1, 2, 9]).tolist() == [True, False, False]
    assert g.bfs(1) == o.bfs(1) == {1: 0}
    assert g.khop(2, 3) == o.khop(2, 3) == {2}
    assert g.get_path(1, 1) == [1]
    assert g.get_path(1, 2) is None


# ---------------------------------------------------------------------------
# GetPath: explicit shortest paths
# ---------------------------------------------------------------------------

def _assert_path_matches(g: WaitFreeGraph, o: SequentialGraph, u: int, v: int):
    """get_path must agree with the oracle on existence and *length*, and be
    a genuine path of the abstract graph (consecutive edges all present)."""
    got = g.get_path(u, v)
    exp = o.path(u, v)
    if exp is None:
        assert got is None
        return
    assert got is not None
    assert got[0] == u and got[-1] == v
    assert len(got) == len(exp)  # shortest-length guarantee
    for a, b in zip(got, got[1:]):
        assert (a, b) in o.edges, (got, (a, b))


def test_get_path_chain_and_shortcut():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4, 5])
    assert g.get_path(1, 5) == [1, 2, 3, 4, 5]
    _apply_both(g, o, [OP_ADD_EDGE], [2], [4])  # shortcut 2 -> 4
    assert g.get_path(1, 5) == [1, 2, 4, 5]  # must take the shortcut
    assert g.get_path(1, 1) == [1]
    assert g.get_path(5, 1) is None
    assert g.get_path(1, 99) is None and g.get_path(99, 1) is None


def test_get_path_batch_shares_snapshot_and_handles_mixed_pairs():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    got = g.get_path_batch([1, 2, 3, 1, 9], [3, 3, 1, 1, 9])
    assert got[0] == [1, 2, 3]
    assert got[1] == [2, 3]
    assert got[2] is None
    assert got[3] == [1]
    assert got[4] is None


def test_get_path_respects_deletion_and_churn():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    _apply_both(g, o, [OP_REMOVE_VERTEX], [2], [0])
    _assert_path_matches(g, o, 1, 4)  # None: cut vertex
    _apply_both(g, o, [OP_ADD_VERTEX], [2], [0])
    _assert_path_matches(g, o, 1, 3)  # still None: stale edges carry no path
    _apply_both(g, o, [OP_ADD_EDGE, OP_ADD_EDGE], [1, 2], [2, 3])
    assert g.get_path(1, 4) == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# incremental CSR maintenance (apply_delta)
# ---------------------------------------------------------------------------

def _assert_csr_bit_identical(got, want, ctx=""):
    for name in want._fields:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        assert a.dtype == b.dtype, (ctx, name, a.dtype, b.dtype)
        assert np.array_equal(a, b), (ctx, name)


def test_apply_delta_insert_delete_readd_sequence():
    """Deterministic churn: inserts, deletes, vertex removal (incident-edge
    invalidation), and re-add (incarnation bump) all fold in bit-identically."""
    g, o = WaitFreeGraph(64, 128, csr_maintenance="rebuild"), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    csr = build_csr(g.state)
    batches = [
        ([OP_ADD_EDGE, OP_ADD_EDGE], [1, 4], [3, 1]),          # inserts
        ([OP_REMOVE_EDGE, OP_ADD_EDGE], [1, 2], [2, 4]),       # delete + insert
        ([OP_REMOVE_VERTEX], [3], [0]),                        # incident drop
        ([OP_ADD_VERTEX, OP_ADD_EDGE], [3, 3], [0, 4]),        # re-add + bind
        ([OP_ADD_EDGE], [1], [2]),                             # tombstone revive
    ]
    for i, (ops, us, vs) in enumerate(batches):
        _apply_both(g, o, ops, us, vs)
        csr = apply_delta(csr, g.state, ops, us, vs)
        _assert_csr_bit_identical(csr, build_csr(g.state), f"batch {i}")
        assert g.snapshot() == (o.vertices, o.edges)


def test_apply_delta_readonly_and_nop_batches_are_free():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    csr = build_csr(g.state)
    out = apply_delta(csr, g.state, [0], [0], [0])  # NOP-only
    assert out is csr  # same object: nothing to fold


def test_apply_delta_falls_back_on_large_delta():
    """A delta above the footprint threshold must fall back to build_csr and
    still be exact."""
    rng = np.random.default_rng(3)
    g, o = WaitFreeGraph(256, 1024, csr_maintenance="rebuild"), SequentialGraph()
    ops, us, vs = sample_batch(rng, 64, "traversal", key_space=KEY_SPACE)
    _apply_both(g, o, ops, us, vs)
    csr = build_csr(g.state)
    ops, us, vs = sample_batch(rng, 512, "traversal", key_space=KEY_SPACE)
    _apply_both(g, o, ops, us, vs)
    out = apply_delta(csr, g.state, ops, us, vs)
    _assert_csr_bit_identical(out, build_csr(g.state), "large delta")


def test_cached_csr_delta_survives_growth_rehash():
    """Growth rehashes every slot mid-stream; the graph must detect it and
    fall back to a rebuild rather than splicing into a moved table."""
    g, o = WaitFreeGraph(8, 8), SequentialGraph()  # tiny: forces growth
    g.traversal_csr()  # prime the cache so delta maintenance engages
    for start in (0, 8, 16):
        keys = list(range(start, start + 8))
        ops = np.full(8, OP_ADD_VERTEX, np.int32)
        _apply_both(g, o, ops, np.asarray(keys, np.int32), np.zeros(8, np.int32))
        edges = [(k, k + 1) for k in keys[:-1]]
        eops = np.full(len(edges), OP_ADD_EDGE, np.int32)
        _apply_both(g, o, eops, np.asarray([a for a, _ in edges], np.int32),
                    np.asarray([b for _, b in edges], np.int32))
        _assert_csr_bit_identical(g.traversal_csr(), build_csr(g.state),
                                  f"after growth wave {start}")
        assert g.snapshot() == (o.vertices, o.edges)


def test_delta_queue_folds_lazily_at_query_time():
    """Update batches between queries are queued, not folded eagerly: the
    cost lands once per query epoch, read-only batches don't disturb the
    queue, and the single fold over the whole queue is bit-identical to a
    rebuild."""
    rng = np.random.default_rng(7)
    g, o = WaitFreeGraph(256, 1024), SequentialGraph()
    ops, us, vs = sample_batch(rng, 128, "traversal", key_space=KEY_SPACE)
    _apply_both(g, o, ops, us, vs)
    g.traversal_csr()  # prime the cache
    for i in range(4):
        ops, us, vs = sample_update_batch(rng, 12, key_space=KEY_SPACE)
        _apply_both(g, o, ops, us, vs)
        assert g._csr is None and len(g._delta_batches) == i + 1  # queued
        assert g.contains_vertex(int(us[0])) in (True, False)  # read-only op
        assert len(g._delta_batches) == i + 1  # queue survived it
    _assert_csr_bit_identical(g.traversal_csr(), build_csr(g.state), "queued fold")
    assert g._delta_batches == []  # folded and cleared
    assert g.snapshot() == (o.vertices, o.edges)


@pytest.mark.parametrize("seed", range(10))
def test_apply_delta_randomized_churn_matches_rebuild(seed):
    """Randomized insert/delete/re-add sequences: the delta-maintained CSR is
    bit-identical to a fresh rebuild after every update batch, and queries
    stay oracle-exact throughout."""
    rng = np.random.default_rng(1000 + seed)
    g = WaitFreeGraph(256, 1024, mode="fpsp")  # csr_maintenance="delta" default
    o = SequentialGraph()
    ops, us, vs = sample_batch(rng, 128, "traversal", key_space=KEY_SPACE)
    _apply_both(g, o, ops, us, vs)
    g.traversal_csr()  # prime the cache
    for _ in range(6):
        ops, us, vs = sample_update_batch(rng, 16, key_space=KEY_SPACE)
        _apply_both(g, o, ops, us, vs)
        _assert_csr_bit_identical(g.traversal_csr(), build_csr(g.state))
        us_q, vs_q = sample_query_pairs(rng, 16, KEY_SPACE)
        got = g.reachable(us_q, vs_q)
        assert got.tolist() == [o.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)]


# ---------------------------------------------------------------------------
# randomized oracle equivalence: 2 modes × 25 seeds = 50 graphs
# ---------------------------------------------------------------------------

def _build_random(seed: int, mode: str):
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(256, 1024, mode=mode)
    oracle = SequentialGraph()
    for _ in range(2):
        ops, us, vs = sample_batch(rng, 192, "traversal", key_space=KEY_SPACE)
        _apply_both(g, oracle, ops, us, vs)
    # deletion wave: tombstones + stale edges
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    _apply_both(g, oracle, np.full(8, OP_REMOVE_VERTEX, np.int32), kill,
                np.zeros(8, np.int32))
    # incarnation churn: re-add half of the killed keys
    revive = kill[:4]
    _apply_both(g, oracle, np.full(4, OP_ADD_VERTEX, np.int32), revive,
                np.zeros(4, np.int32))
    # fresh edges over the churned key space
    ops, us, vs = sample_batch(rng, 96, "traversal", key_space=KEY_SPACE)
    _apply_both(g, oracle, ops, us, vs)
    return g, oracle, rng


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_randomized_graphs_match_oracle(mode, seed):
    g, oracle, rng = _build_random(seed, mode)
    # abstract state agrees
    assert g.snapshot() == (oracle.vertices, oracle.edges)
    # pairwise reachability, one shared snapshot
    us, vs = sample_query_pairs(rng, 64, KEY_SPACE)
    got = g.reachable(us, vs)
    exp = [oracle.reachable(int(a), int(b)) for a, b in zip(us, vs)]
    assert got.tolist() == exp
    # full BFS level maps from random sources
    srcs = rng.integers(0, KEY_SPACE, size=8).tolist()
    for s, levels in zip(srcs, g.bfs_batch(srcs)):
        assert levels == oracle.bfs(int(s))
    # bounded-depth neighborhoods
    u = int(rng.integers(0, KEY_SPACE))
    k = int(rng.integers(0, 4))
    assert g.khop(u, k) == oracle.khop(u, k)


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_randomized_get_path_matches_oracle(mode, seed):
    """GetPath over the same 50 randomized churned graphs: every returned
    path is a valid path of the abstract graph with oracle-shortest length,
    and None exactly when the oracle says unreachable."""
    g, oracle, rng = _build_random(seed, mode)
    us, vs = sample_query_pairs(rng, 12, KEY_SPACE)
    paths = g.get_path_batch(us, vs)
    for u, v, got in zip(us, vs, paths):
        u, v = int(u), int(v)
        exp = oracle.path(u, v)
        if exp is None:
            assert got is None, (u, v, got)
            continue
        assert got is not None, (u, v)
        assert got[0] == u and got[-1] == v
        assert len(got) == len(exp), (u, v, got, exp)  # length-optimality
        assert len(set(got)) == len(got)  # simple path
        for a, b in zip(got, got[1:]):
            assert (a, b) in oracle.edges, (got, (a, b))
