"""Oracle-equivalence tests for the batched traversal engine.

Every query form (pairwise reachability, BFS level maps, k-hop
neighborhoods) and the vectorized snapshot are validated exactly against the
sequential oracle, over deterministic constructions and ≥50 randomized
graphs — including vertex-deletion staleness and incarnation churn, the
Fig. 3 hazards that traversal must respect (a stale edge must never carry a
path)."""

import numpy as np
import pytest

from repro.core import (
    SequentialGraph,
    WaitFreeGraph,
    bfs_levels,
    build_csr,
    run_sequential,
)
from repro.core.types import (
    EMPTY_KEY,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_REMOVE_VERTEX,
)
from repro.core.workloads import sample_batch, sample_query_pairs

KEY_SPACE = 24  # small key space: dense conflicts, real path structure


def _apply_both(g: WaitFreeGraph, oracle: SequentialGraph, ops, us, vs):
    got = g.apply(ops, us, vs)
    exp, _ = run_sequential(ops, us, vs, graph=oracle)
    assert got.tolist() == exp


def _chain(g: WaitFreeGraph, oracle: SequentialGraph, keys):
    n = len(keys)
    ops = np.concatenate([np.full(n, OP_ADD_VERTEX, np.int32),
                          np.full(n - 1, OP_ADD_EDGE, np.int32)])
    us = np.concatenate([np.asarray(keys, np.int32), np.asarray(keys[:-1], np.int32)])
    vs = np.concatenate([np.zeros(n, np.int32), np.asarray(keys[1:], np.int32)])
    _apply_both(g, oracle, ops, us, vs)


# ---------------------------------------------------------------------------
# deterministic semantics
# ---------------------------------------------------------------------------

def test_chain_levels_and_khop():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [10, 11, 12, 13, 14])
    assert g.bfs(10) == o.bfs(10) == {10: 0, 11: 1, 12: 2, 13: 3, 14: 4}
    assert g.bfs(14) == o.bfs(14) == {14: 0}  # directed: no back edges
    for k in range(5):
        assert g.khop(10, k) == o.khop(10, k)
    assert g.khop(10, 2) == {10, 11, 12}


def test_self_reachability_and_absent_endpoints():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2])
    for u, v in [(1, 1), (1, 2), (2, 1), (1, 99), (99, 1), (99, 99)]:
        assert g.reachable(u, v) == o.reachable(u, v)
    assert g.reachable(1, 1) is True     # empty path: u exists
    assert g.reachable(99, 99) is False  # absent vertex
    assert g.bfs(99) == {} == o.bfs(99)
    assert g.khop(99, 3) == set() == o.khop(99, 3)


def test_deleted_vertex_breaks_paths():
    """Removing a cut vertex must sever every path through it."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    assert g.reachable(1, 4) and o.reachable(1, 4)
    _apply_both(g, o, [OP_REMOVE_VERTEX], [3], [0])
    assert g.reachable(1, 4) == o.reachable(1, 4) == False
    assert g.reachable(1, 2) == o.reachable(1, 2) == True
    assert g.bfs(1) == o.bfs(1) == {1: 0, 2: 1}


def test_incarnation_churn_stale_edges_carry_no_path():
    """The Fig. 3 hazard, traversal edition: after remove+re-add of an
    endpoint, the stale edge's binding must not conduct reachability."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    _apply_both(g, o, [OP_REMOVE_VERTEX, OP_ADD_VERTEX], [2, 2], [0, 0])
    # 2 is live again, but edges 1->2 and 2->3 were bound to its old
    # incarnation: nothing is reachable through it.
    assert g.reachable(1, 3) == o.reachable(1, 3) == False
    assert g.reachable(1, 2) == o.reachable(1, 2) == False
    assert g.reachable(2, 3) == o.reachable(2, 3) == False
    assert g.bfs(1) == o.bfs(1) == {1: 0}
    # re-binding the edges at the new incarnation restores the path
    _apply_both(g, o, [OP_ADD_EDGE, OP_ADD_EDGE], [1, 2], [2, 3])
    assert g.reachable(1, 3) == o.reachable(1, 3) == True


def test_batch_queries_share_one_snapshot():
    """All queries in a batch linearize at the same batch boundary: pairs
    issued together see identical state, and the cached CSR is invalidated
    by the next apply."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    csr1 = g.traversal_csr()
    assert g.traversal_csr() is csr1  # cached between applies
    got = g.reachable([1, 1, 2], [2, 3, 3])
    assert got.tolist() == [True, True, True]
    _apply_both(g, o, [OP_REMOVE_VERTEX], [2], [0])
    assert g.traversal_csr() is not csr1  # invalidated
    assert g.reachable([1, 1, 2], [2, 3, 3]).tolist() == [False, False, False]


def test_readonly_batches_keep_cached_snapshot():
    """contains/NOP-only batches leave the abstract graph unchanged, so the
    cached CSR must survive them (queries interleaved with lookups stay
    amortized); any mutating op invalidates it."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    c0 = g.traversal_csr()
    assert g.contains_vertex(1) and g.contains_edge(1, 2)
    assert not g.contains_vertex(99)
    assert g.traversal_csr() is c0
    g.add_vertex(7)
    assert g.traversal_csr() is not c0


def test_csr_structure_invariants():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3, 4])
    _apply_both(g, o, [OP_ADD_EDGE, OP_ADD_EDGE], [1, 1], [3, 4])
    csr = build_csr(g.state)
    src = np.asarray(csr.src)
    dst = np.asarray(csr.dst)
    rs = np.asarray(csr.row_start)
    re = np.asarray(csr.row_end)
    cv = csr.v_capacity
    assert int(csr.n_live) == 4
    assert int(csr.n_edges) == 5
    # sorted by source slot, invalid lanes (== Cv) pushed to the tail
    assert (np.diff(src) >= 0).all()
    assert (src[int(csr.n_edges):] == cv).all() and (dst[int(csr.n_edges):] == cv).all()
    # row ranges partition the valid prefix and degrees sum to edge count
    assert int((re - rs).sum()) == int(csr.n_edges)
    v_key = np.asarray(csr.v_key)
    v_live = np.asarray(csr.v_live)
    deg = {1: 3, 2: 1, 3: 1, 4: 0}
    for j in range(cv):
        if v_live[j]:
            assert int(re[j] - rs[j]) == deg[int(v_key[j])]
            # every out-neighbor slot in the row holds a live vertex
            for t in dst[rs[j]:re[j]]:
                assert v_live[int(t)]


def test_bfs_levels_padding_lanes_are_inert():
    """EMPTY_KEY query lanes (batch padding) must return all -1 rows."""
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2])
    keys = np.asarray([1, EMPTY_KEY, 2, EMPTY_KEY], np.int32)
    lv = np.asarray(bfs_levels(build_csr(g.state), keys))
    assert (lv[1] == -1).all() and (lv[3] == -1).all()
    assert (lv[0] >= 0).sum() == 2 and (lv[2] >= 0).sum() == 1


def test_cyclic_graph_terminates_and_matches():
    g, o = WaitFreeGraph(64, 64), SequentialGraph()
    _chain(g, o, [1, 2, 3])
    _apply_both(g, o, [OP_ADD_EDGE], [3], [1])  # close the cycle
    assert g.reachable(3, 2) == o.reachable(3, 2) == True
    assert g.bfs(2) == o.bfs(2) == {2: 0, 3: 1, 1: 2}


# ---------------------------------------------------------------------------
# randomized oracle equivalence: 2 modes × 25 seeds = 50 graphs
# ---------------------------------------------------------------------------

def _build_random(seed: int, mode: str):
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(256, 1024, mode=mode)
    oracle = SequentialGraph()
    for _ in range(2):
        ops, us, vs = sample_batch(rng, 192, "traversal", key_space=KEY_SPACE)
        _apply_both(g, oracle, ops, us, vs)
    # deletion wave: tombstones + stale edges
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    _apply_both(g, oracle, np.full(8, OP_REMOVE_VERTEX, np.int32), kill,
                np.zeros(8, np.int32))
    # incarnation churn: re-add half of the killed keys
    revive = kill[:4]
    _apply_both(g, oracle, np.full(4, OP_ADD_VERTEX, np.int32), revive,
                np.zeros(4, np.int32))
    # fresh edges over the churned key space
    ops, us, vs = sample_batch(rng, 96, "traversal", key_space=KEY_SPACE)
    _apply_both(g, oracle, ops, us, vs)
    return g, oracle, rng


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_randomized_graphs_match_oracle(mode, seed):
    g, oracle, rng = _build_random(seed, mode)
    # abstract state agrees
    assert g.snapshot() == (oracle.vertices, oracle.edges)
    # pairwise reachability, one shared snapshot
    us, vs = sample_query_pairs(rng, 64, KEY_SPACE)
    got = g.reachable(us, vs)
    exp = [oracle.reachable(int(a), int(b)) for a, b in zip(us, vs)]
    assert got.tolist() == exp
    # full BFS level maps from random sources
    srcs = rng.integers(0, KEY_SPACE, size=8).tolist()
    for s, levels in zip(srcs, g.bfs_batch(srcs)):
        assert levels == oracle.bfs(int(s))
    # bounded-depth neighborhoods
    u = int(rng.integers(0, KEY_SPACE))
    k = int(rng.integers(0, 4))
    assert g.khop(u, k) == oracle.khop(u, k)
