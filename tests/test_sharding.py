"""Hash-prefix sharding: multi-shard bit-identity to the 1-shard oracle.

The acceptance bar for ``repro.core.sharding`` is that ``n_shards`` is a
pure scaling knob: over the 50-churned-graph corpus (25 seeds × 2 engine
modes, deletion + incarnation churn included), ``n_shards ∈ {1, 2, 4}``
must agree on

* per-op success bits (and all must equal the sequential oracle),
* the vertex tables, byte-for-byte — every shard's replica equals the
  1-shard graph's table, placement included,
* the fused ``TraversalCSR`` — ``src``/row offsets/vertex columns/counts
  byte-equal to the 1-shard CSR, and the ``(src, dst)`` edge multiset
  identical (``dst`` order *within* a row follows shard-lane provenance,
  which is layout-dependent by design; every query is scatter-min and
  therefore order-independent — asserted below, not assumed),
* ``reachable`` / ``bfs`` / ``get_path`` results, byte-for-byte,

plus growth: a repeated-doubling stress keeps replicas aligned and answers
exact while per-shard edge capacities evolve independently.
"""

import numpy as np
import pytest

from repro.core import SequentialGraph, WaitFreeGraph, build_csr, run_sequential
from repro.core import sharding
from repro.core.hashing import edge_hash32
from repro.core.types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
)
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    shard_balance,
)

KEY_SPACE = 24
SHARD_COUNTS = (1, 2, 4)


def _assert_same_fields(got, want, ctx="", skip=()):
    for name in want._fields:
        if name in skip:
            continue
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        assert a.dtype == b.dtype, (ctx, name, a.dtype, b.dtype)
        assert np.array_equal(a, b), (ctx, name)


def _churn_stream(seed: int):
    """The test_maintenance churn recipe as a reusable op stream (tombstones
    + incarnation churn — the Fig. 3 hazards)."""
    rng = np.random.default_rng(seed)
    stream = []
    for _ in range(2):
        stream.append(sample_batch(rng, 192, "traversal", key_space=KEY_SPACE))
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    stream.append((np.full(8, OP_REMOVE_VERTEX, np.int32), kill, np.zeros(8, np.int32)))
    stream.append(
        (np.full(4, OP_ADD_VERTEX, np.int32), kill[:4], np.zeros(4, np.int32))
    )
    stream.append(sample_batch(rng, 96, "traversal", key_space=KEY_SPACE))
    return stream, rng


def _build_corpus_case(seed: int, mode: str):
    """One corpus case: the same churn stream through every shard count,
    success bits cross-checked against the oracle at every batch."""
    graphs = {
        n: WaitFreeGraph(256, 1024, mode=mode, n_shards=n) for n in SHARD_COUNTS
    }
    oracle = SequentialGraph()
    stream, rng = _churn_stream(seed)
    for ops, us, vs in stream:
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        for n, g in graphs.items():
            got = g.apply(ops, us, vs)
            assert got.tolist() == exp, f"n_shards={n}: success bits diverge"
    return graphs, oracle, rng


# ---------------------------------------------------------------------------
# routing unit tests
# ---------------------------------------------------------------------------


def test_shard_id_is_hash_prefix():
    """The shard id is literally the top log2(n) bits of the same 32-bit
    hash whose low bits the probe sequence uses — no second hash."""
    rng = np.random.default_rng(0)
    us = rng.integers(0, 1 << 20, 256).astype(np.int32)
    vs = rng.integers(0, 1 << 20, 256).astype(np.int32)
    full = np.asarray(edge_hash32(us, vs)).astype(np.uint32)
    for n, k in ((2, 1), (4, 2), (8, 3)):
        got = sharding.shard_of_edges(us, vs, n)
        assert np.array_equal(got, (full >> np.uint32(32 - k)).astype(np.int32))
        assert got.min() >= 0 and got.max() < n
    assert np.array_equal(
        sharding.shard_of_edges(us, vs, 1), np.zeros(256, np.int32)
    )


def test_route_ops_rewrites_foreign_mutations_read_only():
    """Every shard sees the full batch silhouette: vertex ops untouched,
    owned edge mutations untouched, non-owned edge mutations rewritten to
    OP_CONTAINS_EDGE (never dropped — conflict masks and claim priorities
    must match in every shard)."""
    rng = np.random.default_rng(1)
    ops, us, vs = sample_batch(rng, 256, "traversal", key_space=KEY_SPACE)
    for n in (2, 4):
        shard_ops, owner = sharding.route_ops(ops, us, vs, n)
        assert len(shard_ops) == n and owner.shape == ops.shape
        is_emut = (ops == OP_ADD_EDGE) | (ops == OP_REMOVE_EDGE)
        for s, so in enumerate(shard_ops):
            assert so.shape == ops.shape
            mine = is_emut & (owner == s)
            assert np.array_equal(so[mine], ops[mine])  # owned: verbatim
            foreign = is_emut & (owner != s)
            assert (so[foreign] == OP_CONTAINS_EDGE).all()  # foreign: read-only
            assert np.array_equal(so[~is_emut], ops[~is_emut])  # rest: verbatim
        # each mutation is owned by exactly one shard
        owned_counts = sum(
            (so == ops) & is_emut for so in shard_ops
        )
        assert (owned_counts[is_emut] == 1).all()


def test_shard_balance_histogram():
    rng = np.random.default_rng(2)
    ops, us, vs = sample_batch(rng, 4096, "traversal", key_space=100_000)
    hist = shard_balance(ops, us, vs, 4)
    assert hist.sum() == np.isin(
        ops, (OP_ADD_EDGE, OP_REMOVE_EDGE, OP_CONTAINS_EDGE)
    ).sum()
    # uniform keys -> near-uniform prefixes (loose 2x bound, not a p-value)
    assert hist.max() < 2 * max(1, hist.min())


def test_fuse_single_shard_is_identity_and_state_property_guards():
    g = WaitFreeGraph(64, 256)
    g.apply(*initial_vertices(8))
    csr = build_csr(g.state)
    assert sharding.fuse_csrs([csr]) is csr
    gs = WaitFreeGraph(64, 256, n_shards=2)
    with pytest.raises(AttributeError):
        gs.state
    assert len(gs.shards) == 2


def test_mesh_placement_roundtrip():
    """place_shards is semantically a no-op (pure pytrees, host-local mesh)."""
    states = sharding.make_shard_states(64, 64, 4)
    placed = sharding.place_shards(states, sharding.host_local_mesh())
    for a, b in zip(states, placed):
        _assert_same_fields(a, b, "placement")


# ---------------------------------------------------------------------------
# the 50-churned-graph corpus: bit-identity across shard counts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_corpus_bit_identity_across_shard_counts(mode, seed):
    graphs, oracle, rng = _build_corpus_case(seed, mode)
    g1 = graphs[1]
    st1 = g1.state
    csr1 = g1.traversal_csr()

    for n in SHARD_COUNTS[1:]:
        g = graphs[n]
        # vertex replicas: byte-identical per shard AND to the 1-shard table
        for s, sh in enumerate(g.shards):
            for f in ("v_key", "v_live", "v_inc"):
                assert np.array_equal(
                    np.asarray(getattr(sh, f)), np.asarray(getattr(st1, f))
                ), (n, s, f)
        # fused CSR: everything except intra-row dst/lane order is byte-equal
        fused = g.traversal_csr()
        _assert_same_fields(fused, csr1, f"n_shards={n}", skip=("dst", "lane"))
        # the (src, dst) edge multiset is identical (dst order within a row
        # follows shard-lane provenance — layout, not content)
        ne = int(csr1.n_edges)
        assert int(fused.n_edges) == ne
        p1 = np.lexsort((np.asarray(csr1.dst)[:ne], np.asarray(csr1.src)[:ne]))
        pf = np.lexsort((np.asarray(fused.dst)[:ne], np.asarray(fused.src)[:ne]))
        assert np.array_equal(
            np.asarray(fused.dst)[:ne][pf], np.asarray(csr1.dst)[:ne][p1]
        ), n
        # abstract snapshot: all shard counts and the oracle agree
        assert g.snapshot() == g1.snapshot() == (oracle.vertices, oracle.edges), n

    # queries: byte-identical across shard counts, exact against the oracle
    us_q, vs_q = sample_query_pairs(rng, 16, KEY_SPACE)
    r1 = np.asarray(g1.reachable(us_q, vs_q))
    assert r1.tolist() == [
        oracle.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)
    ]
    bfs_src = [int(k) for k in us_q[:4]]
    b1 = g1.bfs_batch(bfs_src)
    p1 = g1.get_path_batch(us_q[:8], vs_q[:8])
    for n in SHARD_COUNTS[1:]:
        g = graphs[n]
        assert np.array_equal(np.asarray(g.reachable(us_q, vs_q)), r1), n
        assert g.bfs_batch(bfs_src) == b1, n
        # parents ride scatter-min over identical slot numbering, so even
        # the *choice* of shortest path is byte-identical, not just length
        assert g.get_path_batch(us_q[:8], vs_q[:8]) == p1, n


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
def test_delta_maintenance_matches_fused_rebuild(mode):
    """csr_maintenance="delta" on a sharded graph: per-shard folds of the
    routed batches fuse to exactly the fresh per-shard rebuild, chained
    across update batches (rehash-free window)."""
    rng = np.random.default_rng(11)
    g = WaitFreeGraph(256, 1024, mode=mode, n_shards=4)
    oracle = SequentialGraph()
    for ops, us, vs in [initial_vertices(KEY_SPACE)] + [
        sample_batch(rng, 96, "traversal", key_space=KEY_SPACE) for _ in range(2)
    ]:
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        assert g.apply(ops, us, vs).tolist() == exp
    g.traversal_csr()  # prime the per-shard delta bases
    from repro.core.workloads import sample_update_batch

    for i in range(4):
        ops, us, vs = sample_update_batch(rng, 12, key_space=KEY_SPACE)
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        assert g.apply(ops, us, vs).tolist() == exp
        fused = g.traversal_csr()  # one apply_delta per shard + fuse
        fresh = sharding.fuse_csrs([build_csr(st) for st in g.shards])
        _assert_same_fields(fused, fresh, f"batch {i}")
        assert g.snapshot() == (oracle.vertices, oracle.edges)


def test_sharded_growth_seeds_delta_queue_with_snapshot_compact():
    """After a growth retry, each grown shard's pre-compacted snapshot
    becomes that shard's delta base and the retried routed batch its queue
    — the next query folds one batch per shard instead of rebuilding
    (mirrors the 1-shard test in test_maintenance.py)."""
    g = WaitFreeGraph(64, 128, n_shards=2, maintenance_impl="device")
    g.traversal_csr()  # prime the cache
    ops, us, vs = initial_vertices(300)  # forces growth mid-apply
    g.apply(ops, us, vs)
    assert g.shards[0].v_capacity > 64
    assert g._csr is None and g._shard_csr_bases is not None
    assert len(g._delta_batches) == 1
    _assert_same_fields(
        g.traversal_csr(),
        sharding.fuse_csrs([build_csr(st) for st in g.shards]),
        "folded",
    )


# ---------------------------------------------------------------------------
# rehash at growth: synchronized vertex compaction, per-shard edge policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_growth_stress_keeps_replicas_aligned(mode, n_shards):
    """Tiny initial tables force repeated doublings mid-workload: replicas
    must stay byte-identical through every synchronized rehash round, the
    per-shard CSRs must stay fusable (shared vertex slot space), and every
    answer stays oracle-exact."""
    seed = 1000 + ["waitfree", "fpsp"].index(mode) * 2 + n_shards
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(32, 32 * n_shards, mode=mode, n_shards=n_shards)
    oracle = SequentialGraph()
    for wave in range(4):
        lo = 60 * wave
        keys = np.arange(lo, lo + 60, dtype=np.int32)
        batches = [
            (np.full(60, OP_ADD_VERTEX, np.int32), keys, np.zeros(60, np.int32)),
            (
                np.full(20, OP_REMOVE_VERTEX, np.int32),
                keys[rng.choice(60, 20, replace=False)],
                np.zeros(20, np.int32),
            ),
            (
                np.full(50, OP_ADD_EDGE, np.int32),
                rng.integers(lo, lo + 60, 50).astype(np.int32),
                rng.integers(0, lo + 60, 50).astype(np.int32),
            ),
        ]
        for ops, us, vs in batches:
            exp, _ = run_sequential(ops, us, vs, graph=oracle)
            assert g.apply(ops, us, vs).tolist() == exp, wave
        assert g.snapshot() == (oracle.vertices, oracle.edges), wave
        ref = g.shards[0]
        for s, sh in enumerate(g.shards[1:], 1):
            for f in ("v_key", "v_live", "v_inc"):
                assert np.array_equal(
                    np.asarray(getattr(sh, f)), np.asarray(getattr(ref, f))
                ), (wave, s, f)
        fused = g.traversal_csr()
        _assert_same_fields(
            fused, sharding.fuse_csrs([build_csr(st) for st in g.shards]), wave
        )
        us_q, vs_q = sample_query_pairs(rng, 8, 60 * (wave + 1))
        got = np.asarray(g.reachable(us_q, vs_q)).tolist()
        assert got == [
            oracle.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)
        ], wave
    assert g.shards[0].v_capacity >= 32 * 4  # >= 2 doublings actually happened
