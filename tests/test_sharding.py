"""Hash-prefix sharding: partitioned tables, answer-identity to the oracle.

The acceptance bar for ``repro.core.sharding`` is that ``n_shards`` is a
pure scaling knob for *answers* while memory and work scale down: over the
50-churned-graph corpus (25 seeds × 2 engine modes, deletion + incarnation
churn included), ``n_shards ∈ {1, 2, 4}`` must agree on

* per-op success bits (and all must equal the sequential oracle),
* the abstract snapshot and every ``reachable`` / ``bfs`` / ``get_path``
  answer (paths ride canonical min-key parents, so even the *choice* of
  shortest path is identical across layouts),

while each shard's tables hold **only** owned rows: every non-empty vertex
slot's key hash-prefixes to its shard, every non-empty edge slot's key
likewise, and no live vertex is stored twice (O(N/S) per shard, no
replicas).  Routing is a partition — each batch lane lands in exactly one
shard's sub-batch, so per-shard engine work is O(batch/S) plus stab
replies.  Growth keeps all of this through independent per-shard
doublings, and a Zipf/hot-vertex stress keeps it when one shard owns most
of the batch.
"""

import numpy as np
import pytest

from repro.core import SequentialGraph, WaitFreeGraph, run_sequential
from repro.core import sharding
from repro.core.hashing import edge_hash32, vertex_hash32
from repro.core.types import (
    EMPTY_KEY,
    EDGE_OPS,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_NOP,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    VERTEX_OPS,
)
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    shard_balance,
    skewed_update_batch,
)

KEY_SPACE = 24
SHARD_COUNTS = (1, 2, 4)


def _assert_same_fields(got, want, ctx="", skip=()):
    for name in want._fields:
        if name in skip:
            continue
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        assert a.dtype == b.dtype, (ctx, name, a.dtype, b.dtype)
        assert np.array_equal(a, b), (ctx, name)


def _shard_states(g: WaitFreeGraph):
    return list(g.shards) if g.n_shards > 1 else [g.state]


def _assert_partition_invariants(g: WaitFreeGraph, oracle: SequentialGraph, ctx=""):
    """Every shard holds only owned rows; live vertices are globally unique
    and exactly the oracle's vertex set (O(N/S): no replica storage)."""
    states = _shard_states(g)
    n = len(states)
    all_live = []
    for s, st in enumerate(states):
        vk = np.asarray(st.v_key)
        present = vk != EMPTY_KEY
        assert (
            sharding.shard_of_vertices(vk[present], n) == s
        ).all(), (ctx, "vertex row on wrong shard", s)
        eu, ev = np.asarray(st.e_key_u), np.asarray(st.e_key_v)
        epresent = eu != EMPTY_KEY
        assert (
            sharding.shard_of_edges(eu[epresent], ev[epresent], n) == s
        ).all(), (ctx, "edge row on wrong shard", s)
        all_live.append(vk[present & np.asarray(st.v_live)])
    live = np.concatenate(all_live)
    assert len(live) == len(set(live.tolist())), (ctx, "replicated live vertex")
    assert set(live.tolist()) == oracle.vertices, (ctx, "live set diverges")


def _churn_stream(seed: int):
    """The test_maintenance churn recipe as a reusable op stream (tombstones
    + incarnation churn — the Fig. 3 hazards)."""
    rng = np.random.default_rng(seed)
    stream = []
    for _ in range(2):
        stream.append(sample_batch(rng, 192, "traversal", key_space=KEY_SPACE))
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    stream.append((np.full(8, OP_REMOVE_VERTEX, np.int32), kill, np.zeros(8, np.int32)))
    stream.append(
        (np.full(4, OP_ADD_VERTEX, np.int32), kill[:4], np.zeros(4, np.int32))
    )
    stream.append(sample_batch(rng, 96, "traversal", key_space=KEY_SPACE))
    return stream, rng


def _build_corpus_case(seed: int, mode: str):
    """One corpus case: the same churn stream through every shard count,
    success bits cross-checked against the oracle at every batch."""
    graphs = {
        n: WaitFreeGraph(256, 1024, mode=mode, n_shards=n) for n in SHARD_COUNTS
    }
    oracle = SequentialGraph()
    stream, rng = _churn_stream(seed)
    for ops, us, vs in stream:
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        for n, g in graphs.items():
            got = g.apply(ops, us, vs)
            assert got.tolist() == exp, f"n_shards={n}: success bits diverge"
    return graphs, oracle, rng


# ---------------------------------------------------------------------------
# routing unit tests
# ---------------------------------------------------------------------------


def test_shard_id_is_hash_prefix():
    """Both shard ids are literally the top log2(n) bits of the same 32-bit
    hashes whose low bits the probe sequences use — no second hash."""
    rng = np.random.default_rng(0)
    us = rng.integers(0, 1 << 20, 256).astype(np.int32)
    vs = rng.integers(0, 1 << 20, 256).astype(np.int32)
    efull = np.asarray(edge_hash32(us, vs)).astype(np.uint32)
    vfull = np.asarray(vertex_hash32(us)).astype(np.uint32)
    for n, k in ((2, 1), (4, 2), (8, 3)):
        got = sharding.shard_of_edges(us, vs, n)
        assert np.array_equal(got, (efull >> np.uint32(32 - k)).astype(np.int32))
        assert got.min() >= 0 and got.max() < n
        vgot = sharding.shard_of_vertices(us, n)
        assert np.array_equal(vgot, (vfull >> np.uint32(32 - k)).astype(np.int32))
    assert np.array_equal(
        sharding.shard_of_edges(us, vs, 1), np.zeros(256, np.int32)
    )
    assert np.array_equal(
        sharding.shard_of_vertices(us, 1), np.zeros(256, np.int32)
    )


def test_route_ops_is_a_partition():
    """Each non-NOP lane lands in exactly one shard's sub-batch (vertex ops
    on their vertex-hash owner, edge ops on their edge-hash owner); no
    silhouette replication — total routed lanes equal non-NOP lanes."""
    rng = np.random.default_rng(1)
    ops, us, vs = sample_batch(rng, 256, "traversal", key_space=KEY_SPACE)
    ops[::17] = OP_NOP
    for n in (1, 2, 4):
        shard_idx, owner = sharding.route_ops(ops, us, vs, n)
        assert len(shard_idx) == n and owner.shape == ops.shape
        seen = np.concatenate(shard_idx)
        active = np.flatnonzero(ops != OP_NOP)
        assert np.array_equal(np.sort(seen), active)  # partition, no dups
        for s, idx in enumerate(shard_idx):
            assert np.array_equal(idx, np.sort(idx))  # ascending => order kept
            is_vop = np.isin(ops[idx], VERTEX_OPS)
            assert (
                sharding.shard_of_vertices(us[idx][is_vop], n) == s
            ).all()
            is_eop = np.isin(ops[idx], EDGE_OPS)
            assert (
                sharding.shard_of_edges(us[idx][is_eop], vs[idx][is_eop], n) == s
            ).all()


def test_route_ops_subbatches_are_balanced():
    """Uniform keys: the O(batch/S) sub-batch bound is tight in practice —
    no shard receives more than 2× its fair share of 4096 lanes."""
    rng = np.random.default_rng(3)
    ops, us, vs = sample_batch(rng, 4096, "traversal", key_space=100_000)
    for n in (2, 4, 8):
        shard_idx, _ = sharding.route_ops(ops, us, vs, n)
        sizes = np.array([len(i) for i in shard_idx])
        assert sizes.sum() == (ops != OP_NOP).sum()
        assert sizes.max() < 2 * (len(ops) // n)


def test_shard_balance_histogram():
    rng = np.random.default_rng(2)
    ops, us, vs = sample_batch(rng, 4096, "traversal", key_space=100_000)
    hist = shard_balance(ops, us, vs, 4)
    assert hist.sum() == np.isin(
        ops, (OP_ADD_EDGE, OP_REMOVE_EDGE, OP_CONTAINS_EDGE)
    ).sum()
    # uniform keys -> near-uniform prefixes (loose 2x bound, not a p-value)
    assert hist.max() < 2 * max(1, hist.min())
    vhist = sharding.vertex_shard_histogram(us, 4)
    assert vhist.sum() == len(us) and vhist.max() < 2 * max(1, vhist.min())


def test_state_property_guards():
    gs = WaitFreeGraph(64, 256, n_shards=2)
    with pytest.raises(AttributeError):
        gs.state
    assert len(gs.shards) == 2


def test_mesh_placement_roundtrip():
    """place_shards is semantically a no-op (pure pytrees, host-local mesh)."""
    states = sharding.make_shard_states(64, 64, 4)
    placed = sharding.place_shards(states, sharding.host_local_mesh())
    for a, b in zip(states, placed):
        _assert_same_fields(a, b, "placement")


# ---------------------------------------------------------------------------
# the canonical vertex directory
# ---------------------------------------------------------------------------


def test_vertex_directory_is_canonical_across_shard_counts():
    """Directory placement depends only on the live key set, so any shard
    count holding the same abstract graph builds a byte-identical
    directory — the shared slot space fused traversals run in."""
    graphs, oracle, _ = _build_corpus_case(7, "waitfree")
    ref = sharding.build_vertex_directory(_shard_states(graphs[1]))
    assert ref.n_live == len(oracle.vertices)
    assert np.array_equal(ref.v_key[ref.sorted_slot], ref.sorted_key)
    assert np.array_equal(np.sort(ref.sorted_key), ref.sorted_key)
    assert ref.v_live.sum() == ref.n_live
    for n in SHARD_COUNTS[1:]:
        d = sharding.build_vertex_directory(_shard_states(graphs[n]))
        _assert_same_fields(d, ref, f"n_shards={n}")


# ---------------------------------------------------------------------------
# the 50-churned-graph corpus: answer identity + partition invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_corpus_answers_identical_across_shard_counts(mode, seed):
    graphs, oracle, rng = _build_corpus_case(seed, mode)
    g1 = graphs[1]

    for n in SHARD_COUNTS[1:]:
        g = graphs[n]
        _assert_partition_invariants(g, oracle, f"n_shards={n}")
        # abstract snapshot: all shard counts and the oracle agree
        assert g.snapshot() == g1.snapshot() == (oracle.vertices, oracle.edges), n

    # queries: identical across shard counts, exact against the oracle
    us_q, vs_q = sample_query_pairs(rng, 16, KEY_SPACE)
    r1 = np.asarray(g1.reachable(us_q, vs_q))
    assert r1.tolist() == [
        oracle.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)
    ]
    bfs_src = [int(k) for k in us_q[:4]]
    b1 = g1.bfs_batch(bfs_src)
    p1 = g1.get_path_batch(us_q[:8], vs_q[:8])
    for n in SHARD_COUNTS[1:]:
        g = graphs[n]
        assert np.array_equal(np.asarray(g.reachable(us_q, vs_q)), r1), n
        assert g.bfs_batch(bfs_src) == b1, n
        # parents ride canonical min-key ranks over the shared directory, so
        # even the *choice* of shortest path is identical, not just length
        assert g.get_path_batch(us_q[:8], vs_q[:8]) == p1, n


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
def test_sharded_rebuild_matches_singleshard_delta(mode):
    """csr_maintenance="delta" keeps its fold fast path on 1 shard; on
    sharded graphs it degrades to a fused rebuild — both must answer
    identically through a chain of update batches."""
    rng = np.random.default_rng(11)
    g1 = WaitFreeGraph(256, 1024, mode=mode, csr_maintenance="delta")
    g4 = WaitFreeGraph(256, 1024, mode=mode, n_shards=4, csr_maintenance="delta")
    oracle = SequentialGraph()
    from repro.core.workloads import sample_update_batch

    for ops, us, vs in [initial_vertices(KEY_SPACE)] + [
        sample_batch(rng, 96, "traversal", key_space=KEY_SPACE) for _ in range(2)
    ] + [sample_update_batch(rng, 12, key_space=KEY_SPACE) for _ in range(4)]:
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        assert g1.apply(ops, us, vs).tolist() == exp
        assert g4.apply(ops, us, vs).tolist() == exp
        us_q, vs_q = sample_query_pairs(rng, 8, KEY_SPACE)
        assert np.array_equal(
            np.asarray(g1.reachable(us_q, vs_q)),
            np.asarray(g4.reachable(us_q, vs_q)),
        )
        assert g1.snapshot() == g4.snapshot() == (oracle.vertices, oracle.edges)


# ---------------------------------------------------------------------------
# growth: independent per-shard doublings, answers stay exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_growth_stress_partitioned(mode, n_shards):
    """Tiny initial tables force repeated doublings mid-workload: the
    partition invariants must hold after every rehash round (each shard
    still stores only owned rows), per-shard capacities evolve
    independently, and every answer stays oracle-exact."""
    seed = 1000 + ["waitfree", "fpsp"].index(mode) * 2 + n_shards
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(32, 32 * n_shards, mode=mode, n_shards=n_shards)
    oracle = SequentialGraph()
    for wave in range(4):
        lo = 60 * wave
        keys = np.arange(lo, lo + 60, dtype=np.int32)
        batches = [
            (np.full(60, OP_ADD_VERTEX, np.int32), keys, np.zeros(60, np.int32)),
            (
                np.full(20, OP_REMOVE_VERTEX, np.int32),
                keys[rng.choice(60, 20, replace=False)],
                np.zeros(20, np.int32),
            ),
            (
                np.full(50, OP_ADD_EDGE, np.int32),
                rng.integers(lo, lo + 60, 50).astype(np.int32),
                rng.integers(0, lo + 60, 50).astype(np.int32),
            ),
        ]
        for ops, us, vs in batches:
            exp, _ = run_sequential(ops, us, vs, graph=oracle)
            assert g.apply(ops, us, vs).tolist() == exp, wave
        assert g.snapshot() == (oracle.vertices, oracle.edges), wave
        _assert_partition_invariants(g, oracle, f"wave={wave}")
        us_q, vs_q = sample_query_pairs(rng, 8, 60 * (wave + 1))
        got = np.asarray(g.reachable(us_q, vs_q)).tolist()
        assert got == [
            oracle.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)
        ], wave
    # ~160 live vertices over n_shards shards: every shard must have grown
    # past its 32/n_shards seed (doublings are per-shard, not lockstep)
    assert all(sh.v_capacity > 32 // n_shards for sh in g.shards)


# ---------------------------------------------------------------------------
# skew: one shard owns most of the batch (satellite stress)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
def test_hot_vertex_shard_imbalance(mode):
    """Zipf endpoints + one pinned hot vertex: the owner shard receives the
    bulk of the lanes while the others idle — answers must stay exact and
    partition invariants intact even under maximal imbalance."""
    hot = 0
    owner = int(sharding.shard_of_vertices(np.array([hot], np.int32), 4)[0])
    rng = np.random.default_rng(21)
    graphs = {n: WaitFreeGraph(256, 1024, mode=mode, n_shards=n) for n in SHARD_COUNTS}
    oracle = SequentialGraph()
    seen_imbalance = False
    for ops, us, vs in [initial_vertices(KEY_SPACE)] + [
        skewed_update_batch(
            rng, 128, key_space=KEY_SPACE, hot_key=hot, hot_frac=0.6
        )
        for _ in range(4)
    ]:
        vhist = sharding.vertex_shard_histogram(us, 4)
        if vhist[owner] > 2 * vhist.sum() // 4:
            seen_imbalance = True
        exp, _ = run_sequential(ops, us, vs, graph=oracle)
        for n, g in graphs.items():
            assert g.apply(ops, us, vs).tolist() == exp, n
    assert seen_imbalance  # the stress actually stressed routing
    us_q, vs_q = sample_query_pairs(rng, 16, KEY_SPACE)
    r1 = np.asarray(graphs[1].reachable(us_q, vs_q))
    for n in SHARD_COUNTS[1:]:
        g = graphs[n]
        _assert_partition_invariants(g, oracle, f"skew n_shards={n}")
        assert g.snapshot() == (oracle.vertices, oracle.edges), n
        assert np.array_equal(np.asarray(g.reachable(us_q, vs_q)), r1), n
