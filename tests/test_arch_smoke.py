"""Per-architecture smoke tests: reduced config, one train step + one decode
step on CPU; assert output shapes and finiteness (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import LM
from repro.models.layers import padded_vocab


def _batch_for(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    if cfg.n_codebooks > 1:
        tokens = rng.integers(0, cfg.vocab, (B, S, cfg.n_codebooks))
        targets = rng.integers(0, cfg.vocab, (B, S, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, (B, S))
        targets = rng.integers(0, cfg.vocab, (B, S))
    batch = {
        "tokens": jnp.asarray(tokens, jnp.int32),
        "targets": jnp.asarray(targets, jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.xattn_every:
        batch["memory"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            cfg.param_dtype,
        )
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = get_smoke_config(name)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)), f"{name}: grad norm not finite"
    assert float(gnorm) > 0, f"{name}: zero gradient"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step_smoke(name):
    cfg = get_smoke_config(name)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B = 2
    rng = np.random.default_rng(2)
    memory = None
    if cfg.xattn_every:
        memory = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            cfg.param_dtype,
        )
    cache = model.decode_init(B, max_len=64, params=params, memory=memory)

    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    vp = padded_vocab(cfg)
    for i in range(3):
        if cfg.n_codebooks > 1:
            tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1, cfg.n_codebooks)), jnp.int32)
            want_shape = (B, 1, cfg.n_codebooks, vp)
        else:
            tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
            want_shape = (B, 1, vp)
        logits, cache = step(params, tok, cache)
        assert logits.shape == want_shape, (name, logits.shape, want_shape)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), name
    assert int(cache["len"]) == 3


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = get_smoke_config("h2o-danube-3-4b")  # windowed: exercises the ring
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(4)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    hid, _, _ = model.hidden_states(params, toks, run={"remat": False})
    from repro.models import layers as L
    full_logits = L.logits_apply(params["embed"], cfg, hid)

    cache = model.decode_init(B, max_len=S)
    outs = []
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    for i in range(S):
        lg, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_decode_matches_forward_recurrent():
    """Same for the SSM family (state handoff correctness)."""
    cfg = get_smoke_config("rwkv6-3b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(6)
    B, S = 1, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    states = model.init_recurrent_states(B, cfg.param_dtype)
    hid, _, _ = model.hidden_states(params, toks, run={"remat": False}, states=states)
    from repro.models import layers as L
    full_logits = L.logits_apply(params["embed"], cfg, hid)

    cache = model.decode_init(B, max_len=S)
    outs = []
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    for i in range(S):
        lg, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_decode_matches_forward_hybrid():
    """zamba2 group-scan decode (mamba states + shared-attn KV per
    occurrence) must reproduce the training forward logits."""
    cfg = get_smoke_config("zamba2-1.2b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    states = model.init_recurrent_states(B, cfg.param_dtype)
    hid, _, _ = model.hidden_states(params, toks, run={"remat": False}, states=states)
    from repro.models import layers as L
    full_logits = L.logits_apply(params["embed"], cfg, hid)

    cache = model.decode_init(B, max_len=S)
    outs = []
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c))
    for i in range(S):
        lg, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_decode_matches_forward_vlm():
    """llama-3.2-vision group-scan decode (cross-attn KV precomputed per
    group) must reproduce the training forward logits."""
    cfg = get_smoke_config("llama-3.2-vision-11b")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(9))
    rng = np.random.default_rng(10)
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    memory = jnp.asarray(
        rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.1,
        cfg.param_dtype,
    )

    hid, _, _ = model.hidden_states(
        params, toks, memory=memory, run={"remat": False}
    )
    from repro.models import layers as L
    full_logits = L.logits_apply(params["embed"], cfg, hid)

    cache = model.decode_init(B, max_len=S, params=params, memory=memory)
    outs = []
    step = jax.jit(lambda p, t, c: model.decode_step(p, t, c, memory=memory))
    for i in range(S):
        lg, cache = step(params, toks[:, i : i + 1], cache)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        atol=2e-3, rtol=2e-3,
    )
