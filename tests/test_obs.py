"""Wait-free telemetry (repro.obs): the three contracts of
docs/OBSERVABILITY.md, pinned over a churned-graph corpus.

1. **Bit-identity** — obs-on and obs-off runs of the identical op stream
   produce byte-identical table state and query answers, for every mode and
   seed in the corpus.  Every metric is derived from arrays the jitted
   programs compute regardless, so enabling telemetry must never perturb
   the computation.
2. **Shard-invariance** — the abstract-level counters (op counts, inserts,
   the FPSP edge-lane dup split) and the canonical directory probe
   histogram are identical across ``n_shards ∈ {1, 2, 4}``: duplicate
   ``(u, v)`` edge keys co-locate on one shard by construction, and the
   directory's placement depends only on the live key set.  (The *physical*
   per-shard probe histograms are deliberately not shard-invariant.)
3. **Impl-invariance** — ``maintenance_impl="host"`` and
   ``"device_interpret"`` runs agree on tables, physical probe histograms,
   and the engine claim-round histogram (all rehash impls build
   bit-identical tables; claim rounds happen in the engines, not in
   maintenance).
"""

from __future__ import annotations

import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import WaitFreeGraph, maintenance
from repro.core.types import OP_ADD_VERTEX, OP_REMOVE_VERTEX
from repro.core.workloads import sample_batch, sample_query_pairs
from repro.obs import metrics as obsm
from repro.obs import probes

KEY_SPACE = 24  # small key space: dense conflicts, real churn

# the abstract-level counters that must not depend on how the tables are
# partitioned (physical counters — probe hists, per-shard balance — may)
SHARD_INVARIANT_COUNTERS = (
    "apply.batches",
    "apply.ops",
    "engine.vops",
    "engine.eops",
    "engine.inserted",
    "fastpath.eops",
    "fastpath.edge_dup",
)


def _churn_stream(seed: int):
    """One deterministic churned-graph op stream + query batch: bulk
    traversal traffic, a deletion wave, incarnation revivals, fresh edges
    (the tests/test_traversal.py corpus shape)."""
    rng = np.random.default_rng(seed)
    batches = []
    for _ in range(2):
        batches.append(sample_batch(rng, 192, "traversal", key_space=KEY_SPACE))
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    batches.append(
        (np.full(8, OP_REMOVE_VERTEX, np.int32), kill, np.zeros(8, np.int32))
    )
    revive = kill[:4].copy()
    batches.append(
        (np.full(4, OP_ADD_VERTEX, np.int32), revive, np.zeros(4, np.int32))
    )
    batches.append(sample_batch(rng, 96, "traversal", key_space=KEY_SPACE))
    queries = sample_query_pairs(rng, 32, KEY_SPACE)
    return batches, queries


def _run(seed: int, mode: str, *, obs, n_shards: int = 1,
         maintenance_impl=None):
    batches, (qu, qv) = _churn_stream(seed)
    g = WaitFreeGraph(
        256, 1024, mode=mode, n_shards=n_shards,
        maintenance_impl=maintenance_impl, obs=obs,
    )
    for ops, us, vs in batches:
        g.apply(ops, us, vs)
    return g, np.asarray(g.reachable(qu, qv))


def _states(g: WaitFreeGraph):
    return list(g.shards) if g.n_shards > 1 else [g.state]


def _state_bytes(g: WaitFreeGraph):
    return [
        tuple(np.asarray(a).tobytes() for a in st) for st in _states(g)
    ]


# ---------------------------------------------------------------------------
# 1. obs on/off bit-identity: 2 modes x 25 seeds = 50 churned graphs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_obs_on_off_bit_identical(mode, seed):
    g_off, ans_off = _run(seed, mode, obs=False)
    g_on, ans_on = _run(seed, mode, obs=True)
    assert _state_bytes(g_on) == _state_bytes(g_off)
    assert ans_on.tolist() == ans_off.tolist()
    # the enabled run actually observed the traffic it claims to observe
    c = g_on.obs.counters()
    assert c["apply.batches"] == 5
    assert c["apply.ops"] == 192 + 192 + 8 + 4 + 96
    assert c["engine.vops"] + c["engine.eops"] == c["apply.ops"]
    assert g_on.obs.hist_counts("engine.claim_rounds")
    if mode == "fpsp":
        assert c["fastpath.ops"] == c["apply.ops"]
        assert obsm.fastpath_frac(g_on.obs) is not None
    assert not g_off.obs.enabled and g_off.obs.counters() == {}


def test_obs_per_phase_spans_and_probe_health():
    """Sharded apply emits the six-phase span trace; probe_health files the
    physical histograms and they cover exactly the occupied slots."""
    g, _ = _run(0, "fpsp", obs=True, n_shards=2)
    spans = g.obs.dump()["spans"]
    for name in ("graph.apply_sharded", "phase.route", "phase.settle_vertices",
                 "phase.answer_stabs", "phase.gather", "phase.settle_edges"):
        assert name in spans, f"missing span {name}"
    h = g.probe_health()
    from repro.core.types import EMPTY_KEY

    occupied_v = sum(
        int(np.sum(np.asarray(st.v_key) != EMPTY_KEY)) for st in _states(g)
    )
    assert g.obs.hist_counts("probe.vertex") == h["vertex"]
    assert g.obs.hist_counts("probe.edge") == h["edge"]
    assert occupied_v == sum(h["vertex"].values())


# ---------------------------------------------------------------------------
# 2. shard-invariance of abstract counters + canonical directory histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 7])
def test_obs_shard_invariant_counters(seed):
    runs = {}
    for n_shards in (1, 2, 4):
        g, ans = _run(seed, "fpsp", obs=True, n_shards=n_shards)
        runs[n_shards] = (g, ans)
    g1, ans1 = runs[1]
    c1 = g1.obs.counters()
    dir1 = probes.directory_probe_histogram(g1)
    for n_shards in (2, 4):
        g, ans = runs[n_shards]
        assert ans.tolist() == ans1.tolist()
        c = g.obs.counters()
        for name in SHARD_INVARIANT_COUNTERS:
            assert c.get(name) == c1.get(name), (
                f"{name} differs at n_shards={n_shards}: "
                f"{c.get(name)} != {c1.get(name)}"
            )
        # canonical directory placement depends only on the live key set
        assert probes.directory_probe_histogram(g) == dir1
        # edge-lane fast-path fraction is the shard-invariant aggregation
        eops, dup = c["fastpath.eops"], c["fastpath.edge_dup"]
        assert 1.0 - dup / eops == 1.0 - c1["fastpath.edge_dup"] / c1[
            "fastpath.eops"]


# ---------------------------------------------------------------------------
# 3. maintenance-impl invariance: host vs device_interpret
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [3])
def test_obs_maintenance_impl_invariant(seed):
    g_h, ans_h = _run(seed, "fpsp", obs=True, maintenance_impl="host")
    g_d, ans_d = _run(seed, "fpsp", obs=True,
                      maintenance_impl="device_interpret")
    assert _state_bytes(g_h) == _state_bytes(g_d)
    assert ans_h.tolist() == ans_d.tolist()
    assert probes.table_probe_histogram(g_h) == probes.table_probe_histogram(g_d)
    assert (g_h.obs.hist_counts("engine.claim_rounds")
            == g_d.obs.hist_counts("engine.claim_rounds"))


def test_obs_rehash_span_and_claim_rounds():
    """maintenance.rehash records its span + the host placement rounds into
    the ambient registry, and the histograms match across impls' shared
    host-oracle fallback."""
    g, _ = _run(1, "waitfree", obs=True)
    reg = obsm.Registry()
    with obsm.use(reg):
        st, _, ok = maintenance.rehash(
            g.state, 2 * g.state.v_capacity, 2 * g.state.e_capacity,
            impl="host",
        )
    assert ok
    assert reg.counters()["maintenance.rehash"] == 1
    assert "maintenance.rehash.host" in reg.dump()["spans"]
    assert sum(reg.hist_counts("maintenance.claim_rounds").values()) > 0
    # the grown tables are probe-healthy: every key within MAX_PROBES
    h = probes.table_probe_histogram(st)
    assert h["vertex"] and max(h["vertex"]) <= 32


# ---------------------------------------------------------------------------
# switches, schema, renderers
# ---------------------------------------------------------------------------

def test_repro_obs_env_switch(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert not WaitFreeGraph(64, 256).obs.enabled
    monkeypatch.setenv("REPRO_OBS", "1")
    g = WaitFreeGraph(64, 256)
    assert g.obs.enabled
    monkeypatch.setenv("REPRO_OBS", "off")
    assert not WaitFreeGraph(64, 256).obs.enabled
    # explicit flag beats the env
    monkeypatch.setenv("REPRO_OBS", "1")
    assert not WaitFreeGraph(64, 256, obs=False).obs.enabled


def test_registry_dump_schema_roundtrips():
    g, _ = _run(2, "fpsp", obs=True, n_shards=2)
    g.probe_health()
    dump = json.loads(json.dumps(g.obs.dump()))  # JSON-serializable
    assert dump["schema"] == "repro-obs/1"
    assert dump["counters"]["apply.batches"] == 5
    hist = dump["histograms"]["engine.claim_rounds"]
    assert hist["count"] == sum(hist["counts"].values())
    assert set(dump["spans"]) >= {"graph.apply_sharded", "phase.route"}


def _load_tool(name: str):
    path = Path(__file__).resolve().parent.parent / "tools" / f"{name}.py"
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_obs_report_renders_dump_and_bundle(tmp_path, capsys):
    obs_report = _load_tool("obs_report")
    g, _ = _run(4, "fpsp", obs=True)
    g.probe_health()
    single = tmp_path / "dump.json"
    single.write_text(json.dumps(g.obs.dump()))
    assert obs_report.main([str(single)]) == 0
    out = capsys.readouterr().out
    assert "fastpath_frac" in out and "engine.claim_rounds" in out
    bundle = tmp_path / "BENCH_obs.json"
    bundle.write_text(json.dumps(
        {"bench": "x", "backend": "cpu", "quick": True,
         "graphs": {"fpsp/ks24": g.obs.dump()}}
    ))
    assert obs_report.main([str(bundle)]) == 0
    assert "fpsp/ks24" in capsys.readouterr().out


def test_bench_regression_fastpath_gate(tmp_path):
    bench_regression = _load_tool("bench_regression")
    row = dict(impl="delta_host", build="fpsp", graph_size=512, batch=8,
               n_shards=1, snap_ms=1.0, us_per_query=4.0, fastpath_frac=0.95)
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"rows": [row]}))
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps({"rows": [dict(row, fastpath_frac=0.90)]}))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"rows": [dict(row, fastpath_frac=0.70)]}))
    assert bench_regression.main([str(base), str(ok)]) == 0
    assert bench_regression.main([str(base), str(bad)]) == 1
    # pre-obs baselines (no fastpath_frac column) skip the gate gracefully
    old = tmp_path / "old.json"
    old.write_text(json.dumps(
        {"rows": [{k: v for k, v in row.items() if k != "fastpath_frac"}]}
    ))
    assert bench_regression.main([str(old), str(bad)]) == 0
