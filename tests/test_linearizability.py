"""Property-based linearizability tests (hypothesis, with a numpy fallback).

The central invariant of the paper: every concurrent execution is equivalent
to *some* sequential one.  Our engine is stronger — it guarantees equivalence
to the *phase-ordered* sequential execution — so the property is exact
equality of every op result (and of the final abstract graph) against the
sequential oracle, for arbitrary op sequences.

``hypothesis`` is an optional dependency (the ``test`` extra in
pyproject.toml).  When it is missing this file must still collect and still
exercise the property — the seeded numpy fuzz tests at the bottom run the
same oracle-equivalence check over randomized op sequences unconditionally;
the hypothesis shrinking variants layer on top when available.
"""

import numpy as np
import pytest

from repro.core import make_batch, make_state, run_sequential
from repro.core import baselines, engine, fastpath
from repro.core.oracle import SequentialGraph
from repro.core.types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
)

try:  # optional: the module must collect (and run the fallback) without it
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_ALL_OPS = [OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_CONTAINS_VERTEX,
            OP_ADD_EDGE, OP_REMOVE_EDGE, OP_CONTAINS_EDGE]

_ENGINES = {
    "waitfree": engine.apply_batch,
    "fpsp": fastpath.apply_batch_fpsp,
    "lockfree": baselines.apply_lockfree,
}


def _run(fn, seq):
    o = np.array([s[0] for s in seq], np.int32)
    u = np.array([s[1] for s in seq], np.int32)
    v = np.array([s[2] for s in seq], np.int32)
    res = fn(make_state(128, 256), make_batch(o, u, v))
    assert bool(res.ok)
    exp, oracle = run_sequential(o, u, v)
    assert np.asarray(res.success).tolist() == exp
    return res.state, oracle


# ---------------------------------------------------------------------------
# seeded numpy fallback: always collected, always run
# ---------------------------------------------------------------------------

def _random_seq(rng, max_len=48, key_space=6):
    n = int(rng.integers(1, max_len + 1))
    ops = rng.choice(_ALL_OPS, size=n)
    us = rng.integers(0, key_space, size=n)
    vs = rng.integers(0, key_space, size=n)
    return list(zip(ops.tolist(), us.tolist(), vs.tolist()))


@pytest.mark.parametrize("name", list(_ENGINES))
def test_linearizable_numpy_fuzz(name):
    """Same property as the hypothesis tests, from a seeded numpy stream —
    small key space forces dense conflicts, the hard case for helping."""
    rng = np.random.default_rng(0xC0FFEE + len(name))
    n_cases = 12 if name == "lockfree" else 25
    for _ in range(n_cases):
        _run(_ENGINES[name], _random_seq(rng))


def _run_cross_batch(seq1, seq2):
    """Two consecutive batches = one long sequential history."""
    o1, u1, v1 = (np.array(c, np.int32) for c in zip(*seq1))
    o2, u2, v2 = (np.array(c, np.int32) for c in zip(*seq2))
    st1 = make_state(128, 256)
    r1 = engine.apply_batch(st1, make_batch(o1, u1, v1))
    r2 = engine.apply_batch(r1.state, make_batch(o2, u2, v2, phase_base=len(o1)))
    oracle = SequentialGraph()
    e1, oracle = run_sequential(o1, u1, v1, graph=oracle)
    e2, oracle = run_sequential(o2, u2, v2, graph=oracle)
    assert np.asarray(r1.success).tolist() == e1
    assert np.asarray(r2.success).tolist() == e2


def test_cross_batch_state_carries_numpy_fuzz():
    rng = np.random.default_rng(2026)
    for _ in range(10):
        _run_cross_batch(_random_seq(rng), _random_seq(rng))


# ---------------------------------------------------------------------------
# hypothesis variants: shrinking + adversarial generation, when available
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    # small key space forces dense conflicts — the hard case for helping logic
    ops_strategy = st.lists(
        st.tuples(
            st.sampled_from(_ALL_OPS),
            st.integers(min_value=0, max_value=5),
            st.integers(min_value=0, max_value=5),
        ),
        min_size=1,
        max_size=48,
    )

    COMMON = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )

    @settings(max_examples=60, **COMMON)
    @given(ops_strategy)
    def test_waitfree_linearizable(seq):
        _run(engine.apply_batch, seq)

    @settings(max_examples=40, **COMMON)
    @given(ops_strategy)
    def test_fpsp_linearizable(seq):
        _run(fastpath.apply_batch_fpsp, seq)

    @settings(max_examples=25, **COMMON)
    @given(ops_strategy)
    def test_lockfree_linearizable(seq):
        _run(baselines.apply_lockfree, seq)

    @settings(max_examples=30, **COMMON)
    @given(ops_strategy, ops_strategy)
    def test_cross_batch_state_carries(seq1, seq2):
        _run_cross_batch(seq1, seq2)
