"""Fault tolerance: kill/resume, checkpoint validity, elastic re-shard,
straggler shard reconstruction.

The elastic (multi-device) cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps seeing exactly one device.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticTokenStream

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _runner(tmp, **kw):
    import jax

    from repro.launch.train import TrainRunner
    from repro.models.config import ArchConfig

    cfg = ArchConfig(
        name="ft-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, dtype="float32",
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return TrainRunner(cfg, mesh, ckpt_dir=tmp, batch=4, seq=16, **kw)


# ---------------------------------------------------------------------------
# kill / resume
# ---------------------------------------------------------------------------

def test_kill_resume_bitexact(tmp_path):
    """Crash at step 7, resume from the step-5 checkpoint, continue to 10:
    final params must equal an uninterrupted 10-step run (the whole loop —
    data order, optimizer state, schedule — is restart-invariant)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")

    r_ref = _runner(d1)
    r_ref.init_or_restore()
    r_ref.train(10, log_every=100, save_every=5, log=lambda *a: None)
    ref = r_ref.params

    r1 = _runner(d2)
    r1.init_or_restore()
    with pytest.raises(SystemExit):
        r1.train(10, log_every=100, save_every=5, crash_at=7,
                 log=lambda *a: None)
    # deterministic variant of the race: let the async step-5 write land
    # before the replacement node looks (if the crash beats the writer,
    # restore correctly falls back — that path is covered by
    # test_corrupt_checkpoint_is_skipped / partial-dir tests).
    r1.store.wait()

    r2 = _runner(d2)
    assert r2.init_or_restore() == "restored"
    assert r2.step == 5
    r2.train(10, log_every=100, save_every=5, log=lambda *a: None)

    import jax
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(r2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_corrupt_checkpoint_is_skipped(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"w": np.arange(8, dtype=np.float32)}
    store.save(1, tree)
    store.save(2, tree)
    # simulated failure mid-write: payload truncated after manifest landed
    with open(tmp_path / "step_0000000002" / "arrays.npz", "wb") as f:
        f.write(b"garbage")
    assert store.latest_step() == 1  # checksum rejects step 2
    restored = store.restore(1, {"w": np.zeros(8, np.float32)})
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_partial_checkpoint_dir_is_invisible(tmp_path):
    store = CheckpointStore(str(tmp_path))
    os.makedirs(tmp_path / "step_0000000009")  # no manifest: mid-crash dir
    assert store.latest_step() is None


# ---------------------------------------------------------------------------
# elastic re-shard (subprocess: needs >1 device)
# ---------------------------------------------------------------------------

_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointStore

    tmp = sys.argv[1]
    store = CheckpointStore(tmp)
    w = np.arange(64, dtype=np.float32).reshape(8, 8)
    mesh_a = jax.make_mesh((2, 2), ("data", "model"))
    wa = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    store.save(3, {"w": wa})

    for shape, axes in [((4, 1), ("data", "model")), ((1, 4), ("data", "model")),
                        ((8,), ("data",))]:
        mesh_b = jax.make_mesh(shape, axes)
        sh = {"w": NamedSharding(mesh_b, P("data"))}
        out = store.restore(3, {"w": jax.ShapeDtypeStruct((8, 8), np.float32)},
                            shardings=sh)
        np.testing.assert_array_equal(np.asarray(out["w"]), w)
        assert out["w"].sharding == sh["w"]  # actually resharded onto mesh_b
    print("ELASTIC_OK")
""")


def test_elastic_mesh_restore(tmp_path):
    r = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# straggler mitigation: any host reconstructs any shard deterministically
# ---------------------------------------------------------------------------

def test_straggler_shard_reconstruction():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=16, seed=9)
    hosts = [SyntheticTokenStream(cfg, host_id=h, n_hosts=4) for h in range(4)]
    # advance to step 5
    batches = None
    for _ in range(5):
        batches = [h.next_batch() for h in hosts]
    # host 2 is a straggler/dead: host 0 recomputes host 2's shard for step 4
    rescue = SyntheticTokenStream(cfg, host_id=2, n_hosts=4)
    rescue.load_state_dict({"step": 4, "seed": 9})
    again = rescue.next_batch()
    np.testing.assert_array_equal(again["tokens"], batches[2]["tokens"])
    np.testing.assert_array_equal(again["targets"], batches[2]["targets"])


def test_global_batch_invariant_to_host_count():
    cfg = DataConfig(vocab=512, seq_len=32, global_batch=16, seed=9)
    one = SyntheticTokenStream(cfg, host_id=0, n_hosts=1).next_batch()
    parts = [
        SyntheticTokenStream(cfg, host_id=h, n_hosts=4).next_batch()
        for h in range(4)
    ]
    np.testing.assert_array_equal(
        one["tokens"], np.concatenate([p["tokens"] for p in parts])
    )
