"""Spec conformance: every assigned architecture carries the exact
public-literature configuration from the assignment table."""

import pytest

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config

# (layers, d_model, heads, kv_heads, d_ff, vocab) straight from the brief
SPEC = {
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
    "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "rwkv6-3b": (32, 2560, 0, 0, 8960, 65536),
    "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_exact_config(name):
    cfg = get_config(name)
    L, d, h, kv, ff, v = SPEC[name]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_family_features():
    assert get_config("granite-moe-3b-a800m").moe.top_k == 8
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
    assert get_config("mixtral-8x7b").window is not None          # SWA
    assert get_config("h2o-danube-3-4b").window is not None       # SWA
    assert get_config("qwen2-7b").qkv_bias                        # QKV bias
    assert get_config("zamba2-1.2b").ssm.state == 64              # ssm_state
    assert get_config("zamba2-1.2b").shared_attn_every
    assert get_config("llama-3.2-vision-11b").xattn_every
    assert get_config("musicgen-medium").n_codebooks > 1
    assert get_config("rwkv6-3b").family == "ssm"


def test_long_500k_skip_policy():
    """long_500k runs iff sub-quadratic (SWA / SSM / hybrid)."""
    runnable = {
        name for name in ARCH_NAMES
        if cell_is_runnable(get_config(name), "long_500k")
    }
    assert runnable == {
        "h2o-danube-3-4b", "mixtral-8x7b", "rwkv6-3b", "zamba2-1.2b"
    }
    for name in ARCH_NAMES:  # every other shape always runs
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_is_runnable(get_config(name), shape)


def test_shapes_table():
    assert SHAPES["train_4k"] == dict(seq_len=4096, global_batch=256, kind="train")
    assert SHAPES["prefill_32k"] == dict(seq_len=32768, global_batch=32, kind="prefill")
    assert SHAPES["decode_32k"] == dict(seq_len=32768, global_batch=128, kind="decode")
    assert SHAPES["long_500k"] == dict(seq_len=524288, global_batch=1, kind="decode")
