"""Device-side state maintenance: rehash, snapshot-compact, delta-merge.

The acceptance bar for ``repro.core.maintenance`` is *bit-identity*: every
impl ("host" numpy oracle, "device" jnp/Pallas, "device_interpret") must
produce byte-for-byte the same tables and the same CSR as the references,
over ≥50 randomized graphs with deletion and incarnation churn, plus a
stress workload that forces repeated growth mid-stream."""

import numpy as np
import pytest

from repro.core import SequentialGraph, WaitFreeGraph, build_csr, run_sequential
from repro.core import maintenance, traversal
from repro.core.graph import _rehash
from repro.core.types import (
    EMPTY_KEY,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_REMOVE_VERTEX,
)
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    sample_update_batch,
)

KEY_SPACE = 24

DEVICE_IMPLS = ("device", "device_interpret")


def _assert_same_fields(got, want, ctx=""):
    for name in want._fields:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        assert a.dtype == b.dtype, (ctx, name, a.dtype, b.dtype)
        assert np.array_equal(a, b), (ctx, name)


def _apply_both(g: WaitFreeGraph, oracle: SequentialGraph, ops, us, vs):
    got = g.apply(ops, us, vs)
    exp, _ = run_sequential(ops, us, vs, graph=oracle)
    assert got.tolist() == exp


def _build_churned(seed: int, mode: str = "waitfree") -> tuple:
    """A randomized graph with tombstones and incarnation churn — the same
    recipe as test_traversal's ``_build_random`` (Fig. 3 hazards included)."""
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(256, 1024, mode=mode, maintenance_impl="host")
    oracle = SequentialGraph()
    for _ in range(2):
        ops, us, vs = sample_batch(rng, 192, "traversal", key_space=KEY_SPACE)
        _apply_both(g, oracle, ops, us, vs)
    kill = rng.choice(KEY_SPACE, size=8, replace=False).astype(np.int32)
    _apply_both(g, oracle, np.full(8, OP_REMOVE_VERTEX, np.int32), kill,
                np.zeros(8, np.int32))
    revive = kill[:4]
    _apply_both(g, oracle, np.full(4, OP_ADD_VERTEX, np.int32), revive,
                np.zeros(4, np.int32))
    ops, us, vs = sample_batch(rng, 96, "traversal", key_space=KEY_SPACE)
    _apply_both(g, oracle, ops, us, vs)
    return g, oracle, rng


# ---------------------------------------------------------------------------
# rehash: device vs host oracle, bit-identical (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_rehash_device_bit_identical_to_host_oracle(mode, seed):
    """2 modes × 25 seeds = 50 randomized churned graphs: the device rehash
    (jnp reference primitives) matches the numpy host oracle byte-for-byte,
    at growth capacities and at same-capacity pure compaction."""
    g, oracle, _ = _build_churned(seed, mode)
    state = g.state
    cases = [
        (2 * state.v_capacity, 2 * state.e_capacity),
        (state.v_capacity, state.e_capacity),  # pure compaction
    ]
    for new_vcap, new_ecap in cases:
        ref, _, ok_h = maintenance.rehash(state, new_vcap, new_ecap, impl="host")
        dev, _, ok_d = maintenance.rehash(state, new_vcap, new_ecap, impl="device")
        assert ok_h and ok_d
        _assert_same_fields(dev, ref, f"caps {new_vcap}x{new_ecap}")
        # the compacted state still represents the oracle's abstract graph
        g2 = WaitFreeGraph()
        g2.state = dev
        assert g2.snapshot() == (oracle.vertices, oracle.edges)


@pytest.mark.parametrize("seed", range(5))
def test_rehash_interpret_kernel_matches_host(seed):
    """The Pallas kernels through the interpreter produce the same tables
    and the same ready-made CSR (deep sweep lives in the device leg above;
    this pins the kernel path itself)."""
    g, _, _ = _build_churned(seed)
    state = g.state
    ref, csr_h, _ = maintenance.rehash(
        state, 2 * state.v_capacity, 2 * state.e_capacity, impl="host", with_csr=True
    )
    ker, csr_k, _ = maintenance.rehash(
        state, 2 * state.v_capacity, 2 * state.e_capacity,
        impl="device_interpret", with_csr=True,
    )
    _assert_same_fields(ker, ref, "state")
    _assert_same_fields(csr_k, csr_h, "csr")


@pytest.mark.parametrize("impl", ["host", *DEVICE_IMPLS])
def test_rehash_snapshot_compact_matches_build_csr(impl):
    """``with_csr=True`` hands back exactly ``build_csr`` of the new state —
    the "free" post-growth snapshot."""
    g, _, _ = _build_churned(99)
    state = g.state
    new_state, csr, ok = maintenance.rehash(
        state, 2 * state.v_capacity, 2 * state.e_capacity, impl=impl, with_csr=True
    )
    assert ok and csr is not None
    _assert_same_fields(csr, build_csr(new_state), impl)


def test_rehash_physical_deletion_invariants():
    """Device rehash obeys the Harris physical-deletion contract: every
    occupied slot is live, every surviving edge is bound to both endpoints'
    current incarnations (mirrors TestRehashPhysicalDeletion for the host)."""
    g, oracle, _ = _build_churned(7)
    state, _, ok = maintenance.rehash(
        g.state, g.state.v_capacity, g.state.e_capacity, impl="device"
    )
    assert ok
    v_key = np.asarray(state.v_key)
    v_live = np.asarray(state.v_live)
    occupied = v_key != EMPTY_KEY
    assert (v_live == occupied).all()
    inc_of = {int(k): int(i) for k, i in
              zip(v_key[occupied], np.asarray(state.v_inc)[occupied])}
    e_occ = np.asarray(state.e_key_u) != EMPTY_KEY
    assert (np.asarray(state.e_live) == e_occ).all()
    for u, v, bu, bv in zip(
        np.asarray(state.e_key_u)[e_occ],
        np.asarray(state.e_key_v)[e_occ],
        np.asarray(state.e_inc_u)[e_occ],
        np.asarray(state.e_inc_v)[e_occ],
    ):
        assert inc_of.get(int(u)) == int(bu)
        assert inc_of.get(int(v)) == int(bv)


def test_rehash_empty_and_vertex_only_states():
    """Degenerate inputs: empty tables and edge-free graphs compact cleanly
    on every impl."""
    for impl in ("host", *DEVICE_IMPLS):
        g = WaitFreeGraph(64, 64)
        st, _, ok = maintenance.rehash(g.state, 128, 128, impl=impl)
        assert ok
        assert int((np.asarray(st.v_key) != EMPTY_KEY).sum()) == 0
        g.apply(*initial_vertices(10))
        st2, _, ok2 = maintenance.rehash(g.state, 128, 128, impl=impl)
        assert ok2
        assert int(np.asarray(st2.v_live).sum()) == 10


def test_rehash_wrapper_escalates_capacity():
    """graph._rehash keeps its 3-arg contract and always returns a state
    whose placement the engines can locate (MAX_PROBES bound)."""
    g, oracle, _ = _build_churned(3)
    out = _rehash(g.state, g.state.v_capacity, g.state.e_capacity)
    g2 = WaitFreeGraph()
    g2.state = out
    assert g2.snapshot() == (oracle.vertices, oracle.edges)


# ---------------------------------------------------------------------------
# growth under churn: repeated mid-workload doublings on the device path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["host", *DEVICE_IMPLS])
@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
def test_growth_stress_mid_workload(mode, impl):
    """Tiny initial tables + key space far beyond them: every few batches
    trips another doubling while deletions keep churning incarnations.
    Oracle equivalence and snapshot/CSR consistency must hold at every
    step, for every maintenance impl."""
    # deterministic per-param seed (string hash() is salted per process —
    # a hash-derived seed would make failures unreproducible)
    seed = ["waitfree", "fpsp"].index(mode) * 3 + ["host", *DEVICE_IMPLS].index(impl)
    rng = np.random.default_rng(1000 + seed)
    g = WaitFreeGraph(32, 32, mode=mode, maintenance_impl=impl)
    oracle = SequentialGraph()
    for wave in range(4):
        lo = 60 * wave
        keys = np.arange(lo, lo + 60, dtype=np.int32)
        _apply_both(g, oracle, np.full(60, OP_ADD_VERTEX, np.int32), keys,
                    np.zeros(60, np.int32))
        kill = keys[rng.choice(60, 20, replace=False)]
        _apply_both(g, oracle, np.full(20, OP_REMOVE_VERTEX, np.int32), kill,
                    np.zeros(20, np.int32))
        eu = rng.integers(lo, lo + 60, 50).astype(np.int32)
        ev = rng.integers(0, lo + 60, 50).astype(np.int32)
        _apply_both(g, oracle, np.full(50, OP_ADD_EDGE, np.int32), eu, ev)
        # queries + snapshot stay exact right after each growth wave
        assert g.snapshot() == (oracle.vertices, oracle.edges)
        _assert_same_fields(g.traversal_csr(), build_csr(g.state), f"wave {wave}")
    assert g.state.v_capacity >= 32 * 4  # >= 2 doublings actually happened


def test_growth_seeds_delta_queue_with_snapshot_compact():
    """After a growth retry, the pre-compacted grown snapshot becomes the
    delta base and the retried batch its queue — the next query folds one
    batch instead of rebuilding."""
    g = WaitFreeGraph(64, 64, maintenance_impl="device")
    g.traversal_csr()  # prime the cache
    ops, us, vs = initial_vertices(300)  # forces growth mid-apply
    g.apply(ops, us, vs)
    assert g.state.v_capacity > 64
    assert g._csr is None and g._delta_base is not None
    assert len(g._delta_batches) == 1
    _assert_same_fields(g.traversal_csr(), build_csr(g.state), "folded")


# ---------------------------------------------------------------------------
# delta-merge: the device searchsorted splice
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", DEVICE_IMPLS)
def test_delta_merge_deterministic_sequence(impl):
    """The deterministic churn sequence from test_traversal, through the
    device merge: inserts, deletes, vertex removal (incident-edge
    invalidation), re-add (incarnation bump), tombstone revive."""
    g = WaitFreeGraph(64, 128, csr_maintenance="rebuild")
    o = SequentialGraph()
    seq = [(OP_ADD_VERTEX, k, 0) for k in (1, 2, 3, 4)]
    seq += [(OP_ADD_EDGE, k, k + 1) for k in (1, 2, 3)]
    ops, us, vs = (np.asarray(c, np.int32) for c in zip(*seq))
    _apply_both(g, o, ops, us, vs)
    csr = build_csr(g.state)
    batches = [
        ([OP_ADD_EDGE, OP_ADD_EDGE], [1, 4], [3, 1]),
        ([5, OP_ADD_EDGE], [1, 2], [2, 4]),       # OP_REMOVE_EDGE + insert
        ([OP_REMOVE_VERTEX], [3], [0]),
        ([OP_ADD_VERTEX, OP_ADD_EDGE], [3, 3], [0, 4]),
        ([OP_ADD_EDGE], [1], [2]),
    ]
    for i, (ops, us, vs) in enumerate(batches):
        _apply_both(g, o, ops, us, vs)
        csr = traversal.apply_delta(csr, g.state, ops, us, vs, impl=impl)
        _assert_same_fields(csr, build_csr(g.state), f"batch {i}")


@pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
@pytest.mark.parametrize("seed", range(25))
def test_delta_merge_randomized_churn_matches_rebuild(mode, seed):
    """50 randomized churned graphs: the device merge chained across update
    batches stays bit-identical to a fresh rebuild, and host and device
    folds agree with each other at every step."""
    g, oracle, rng = _build_churned(seed, mode)
    csr_dev = build_csr(g.state)
    csr_host = csr_dev
    for _ in range(4):
        ops, us, vs = sample_update_batch(rng, 16, key_space=KEY_SPACE)
        _apply_both(g, oracle, ops, us, vs)
        csr_dev = traversal.apply_delta(csr_dev, g.state, ops, us, vs, impl="device")
        csr_host = traversal.apply_delta(csr_host, g.state, ops, us, vs, impl="host")
        want = build_csr(g.state)
        _assert_same_fields(csr_dev, want, "device")
        _assert_same_fields(csr_host, want, "host")
        us_q, vs_q = sample_query_pairs(rng, 16, KEY_SPACE)
        got = traversal.reachable(csr_dev, us_q, vs_q)
        exp = [oracle.reachable(int(a), int(b)) for a, b in zip(us_q, vs_q)]
        assert np.asarray(got).tolist() == exp


def test_delta_merge_via_graph_flag():
    """WaitFreeGraph(maintenance_impl=...) threads the impl through the
    lazy delta-fold path; the folded snapshot equals a rebuild."""
    for impl in DEVICE_IMPLS:
        rng = np.random.default_rng(11)
        g = WaitFreeGraph(256, 1024, maintenance_impl=impl)
        o = SequentialGraph()
        ops, us, vs = sample_batch(rng, 128, "traversal", key_space=KEY_SPACE)
        _apply_both(g, o, ops, us, vs)
        g.traversal_csr()
        for _ in range(3):
            ops, us, vs = sample_update_batch(rng, 12, key_space=KEY_SPACE)
            _apply_both(g, o, ops, us, vs)
        _assert_same_fields(g.traversal_csr(), build_csr(g.state), impl)
        assert g.snapshot() == (o.vertices, o.edges)
