"""Per-kernel sweeps: shapes × dtypes, interpret-mode vs pure-jnp oracle."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.compact import (
    masked_compact,
    masked_compact_reference,
    probe_place,
    probe_place_reference,
)
from repro.kernels.flash_attention import attention, mha_chunked, mha_reference
from repro.kernels.frontier import frontier_expand, frontier_expand_reference
from repro.kernels.hash_probe import hash_probe, hash_probe_reference
from repro.kernels.paged_attention import paged_attention, paged_attention_reference
from repro.kernels.ssd_scan import (
    linear_scan_chunked,
    linear_scan_reference,
    linear_scan_step,
    ssd_scan,
)

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}
RTOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Sk,D,causal,window",
    [
        (1, 2, 2, 32, 32, 16, True, None),     # MHA causal
        (2, 4, 2, 64, 64, 32, True, None),     # GQA
        (1, 8, 1, 32, 32, 64, True, None),     # MQA
        (2, 4, 2, 64, 64, 32, True, 16),       # sliding window
        (1, 2, 2, 16, 48, 32, False, None),    # cross (Sq != Sk, no causal)
        (1, 2, 2, 32, 40, 16, True, None),     # non-multiple Sk (padding)
    ],
)
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, D, causal, window, dtype):
    rng = np.random.default_rng(hash((B, Hq, Sq, Sk, D, causal, str(window))) % 2**32)
    q = _rand(rng, (B, Hq, Sq, D), dtype)
    k = _rand(rng, (B, Hkv, Sk, D), dtype)
    v = _rand(rng, (B, Hkv, Sk, D), dtype)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    got = attention(
        q, k, v, causal=causal, window=window,
        impl="kernel_interpret", block_q=16, block_k=16,
    )
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32),
        atol=ATOL[dtype], rtol=RTOL[dtype],
    )


def test_chunked_matches_reference_large_window():
    rng = np.random.default_rng(0)
    q = _rand(rng, (1, 4, 128, 32), jnp.float32)
    k = _rand(rng, (1, 2, 128, 32), jnp.float32)
    v = _rand(rng, (1, 2, 128, 32), jnp.float32)
    for window in (None, 32, 100):
        ref = mha_reference(q, k, v, causal=True, window=window)
        got = mha_chunked(q, k, v, causal=True, window=window, block_k=32)
        np.testing.assert_allclose(got, ref, atol=2e-5, rtol=2e-5)


def test_chunked_decode_offset():
    """Decode: Sq=1 positioned at the cache tail via q_offset."""
    rng = np.random.default_rng(1)
    k = _rand(rng, (2, 2, 64, 16), jnp.float32)
    v = _rand(rng, (2, 2, 64, 16), jnp.float32)
    qfull = _rand(rng, (2, 2, 64, 16), jnp.float32)
    ref = mha_reference(qfull, k, v, causal=True)
    got = mha_chunked(qfull[:, :, -1:], k, v, causal=True, q_offset=63, block_k=16)
    np.testing.assert_allclose(got[:, :, 0], ref[:, :, -1], atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,D,P,page,ppseq",
    [
        (2, 4, 4, 16, 8, 8, 2),     # MHA
        (3, 8, 2, 32, 16, 8, 4),    # GQA
        (1, 12, 1, 64, 8, 16, 3),   # MQA, larger pages
    ],
)
def test_paged_attention_sweep(B, Hq, Hkv, D, P, page, ppseq, dtype):
    rng = np.random.default_rng(hash((B, Hq, Hkv, D, P, page, ppseq)) % 2**32)
    q = _rand(rng, (B, Hq, D), dtype)
    kp = _rand(rng, (P, page, Hkv, D), dtype)
    vp = _rand(rng, (P, page, Hkv, D), dtype)
    bt = jnp.asarray(
        rng.choice(P, size=(B, ppseq), replace=False if B * ppseq <= P else True)
        .astype(np.int32)
    )
    sl = jnp.asarray(rng.integers(1, page * ppseq + 1, size=(B,)).astype(np.int32))
    ref = paged_attention_reference(q, kp, vp, bt, sl)
    got = paged_attention(q, kp, vp, bt, sl, impl="kernel_interpret")
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32),
        atol=ATOL[dtype], rtol=RTOL[dtype],
    )


# ---------------------------------------------------------------------------
# ssd / gated linear attention scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,S,K,V,chunk,scalar",
    [
        (1, 2, 64, 8, 8, 16, False),
        (2, 3, 128, 16, 24, 32, False),
        (2, 2, 128, 32, 32, 64, True),     # Mamba-2 scalar-decay MXU path
        (1, 1, 256, 64, 64, 64, False),    # RWKV-ish head dims
    ],
)
def test_ssd_scan_sweep(B, H, S, K, V, chunk, scalar, dtype):
    rng = np.random.default_rng(hash((B, H, S, K, V, chunk, scalar)) % 2**32)
    q = _rand(rng, (B, H, S, K), dtype) * 0.5
    k = _rand(rng, (B, H, S, K), dtype) * 0.5
    v = _rand(rng, (B, H, S, V), dtype) * 0.5
    if scalar:
        w = jnp.broadcast_to(
            jnp.asarray(rng.uniform(0.05, 1.0, (B, H, S, 1)), jnp.float32), (B, H, S, K)
        ).astype(dtype)
    else:
        w = jnp.asarray(rng.uniform(0.01, 1.0, (B, H, S, K)), jnp.float32).astype(dtype)
    ref, _ = linear_scan_reference(q, k, v, w)
    got = ssd_scan(q, k, v, w, chunk=chunk, scalar_decay=scalar, impl="kernel_interpret")
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_ssd_chunked_final_state_feeds_decode():
    """Train-to-serve continuity: chunked final state == reference, and the
    O(1) decode step continues it exactly."""
    rng = np.random.default_rng(5)
    B, H, S, K, V = 1, 2, 64, 8, 8
    q = _rand(rng, (B, H, S + 1, K), jnp.float32)
    k = _rand(rng, (B, H, S + 1, K), jnp.float32)
    v = _rand(rng, (B, H, S + 1, V), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, (B, H, S + 1, K)), jnp.float32)

    full, _ = linear_scan_reference(q, k, v, w)
    _, h = linear_scan_chunked(q[:, :, :S], k[:, :, :S], v[:, :, :S], w[:, :, :S], chunk=16)
    y, _ = linear_scan_step(q[:, :, S], k[:, :, S], v[:, :, S], w[:, :, S], h)
    np.testing.assert_allclose(y, full[:, :, S], atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# hash probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap,n", [(64, 16), (256, 64), (1024, 256)])
def test_hash_probe_sweep(cap, n):
    rng = np.random.default_rng(cap * 31 + n)
    # build a table via the engine's own claim path for realism
    from repro.core.locate import claim_vertex_slots
    from repro.core.types import EMPTY_KEY

    table = jnp.full((cap,), EMPTY_KEY, jnp.int32)
    present = jnp.asarray(
        rng.choice(10_000, size=cap // 4, replace=False).astype(np.int32)
    )
    table, _, over, _ = claim_vertex_slots(table, present, jnp.ones((cap // 4,), bool))
    assert not bool(over)

    # queries: half present, half absent
    absent = jnp.asarray((10_000 + rng.integers(0, 1000, n // 2)).astype(np.int32))
    queries = jnp.concatenate([present[: n - n // 2], absent])

    f_ref, e_ref = hash_probe_reference(table, queries)
    f_ker, e_ker = hash_probe(table, queries, impl="kernel_interpret")
    np.testing.assert_array_equal(f_ker, f_ref)
    np.testing.assert_array_equal(e_ker, e_ref)
    # semantic check: every present query found, every absent one got an
    # insert candidate
    f = np.asarray(f_ref)
    assert (f[: n - n // 2] >= 0).all()
    assert (f[n - n // 2:] == -1).all()
    assert (np.asarray(e_ref)[n - n // 2:] >= 0).all()


# ---------------------------------------------------------------------------
# frontier expansion (BFS level step; deep coverage in test_frontier_kernel.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,C,Ce", [(4, 64, 256), (8, 130, 1024), (16, 512, 4096)])
def test_frontier_expand_sweep(S, C, Ce):
    rng = np.random.default_rng(S * 131 + C * 7 + Ce)
    frontier = jnp.asarray(rng.random((S, C)) < 0.2)
    src = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, C, Ce).astype(np.int32))
    ref = frontier_expand_reference(frontier, src, dst)
    got = frontier_expand(frontier, src, dst, impl="kernel_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# compaction primitives (state maintenance; deep coverage in
# test_maintenance.py — these sweep the raw kernels vs the jnp references)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,N,density", [(1, 64, 0.5), (3, 1000, 0.2), (6, 4096, 0.8)])
def test_masked_compact_sweep(R, N, density):
    rng = np.random.default_rng(R * 17 + N)
    vals = jnp.asarray(rng.integers(-5, 1000, (R, N)).astype(np.int32))
    mask = jnp.asarray(rng.random(N) < density)
    ref, n_ref = masked_compact_reference(vals, mask, fill=-1)
    got, n_got = masked_compact(vals, mask, fill=-1, impl="kernel_interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(n_got) == int(n_ref) == int(np.asarray(mask).sum())
    # semantic: survivors in lane order, fill tail
    np.testing.assert_array_equal(
        np.asarray(ref)[:, : int(n_ref)], np.asarray(vals)[:, np.asarray(mask)]
    )
    assert (np.asarray(ref)[:, int(n_ref):] == -1).all()


@pytest.mark.parametrize("cap,n,max_probes", [(64, 16, 32), (256, 100, 32), (1024, 500, 32)])
def test_probe_place_sweep(cap, n, max_probes):
    from repro.core.hashing import hash_vertex

    rng = np.random.default_rng(cap + n)
    keys = jnp.asarray(rng.choice(100_000, n, replace=False).astype(np.int32))
    home = hash_vertex(keys, cap)
    active = jnp.asarray(rng.random(n) < 0.9)
    s_ref, o_ref = probe_place_reference(home, active, capacity=cap, max_probes=max_probes)
    s_got, o_got = probe_place(
        home, active, capacity=cap, max_probes=max_probes, impl="kernel_interpret"
    )
    np.testing.assert_array_equal(np.asarray(s_got), np.asarray(s_ref))
    assert bool(o_got) == bool(o_ref) is False
    s = np.asarray(s_ref)
    a = np.asarray(active)
    assert (s[~a] == -1).all() and (s[a] >= 0).all()
    assert len(set(s[a].tolist())) == int(a.sum())  # distinct slots
    # wait-free locate invariant: no empty slot strictly earlier on a
    # placed key's own probe chain (else the engines' locate would stop
    # at the gap and miss the key)
    occ = np.zeros(cap, bool)
    occ[s[a]] = True
    hm = np.asarray(home)
    for i in np.flatnonzero(a):
        for step in range(max_probes):
            slot = (hm[i] + step * (step + 1) // 2) & (cap - 1)
            if slot == s[i]:
                break
            assert occ[slot], (i, step)


def test_probe_slot_replica_pins_hashing():
    """compact.ref keeps a local probe_slot replica (kernel families are
    import-free of repro.core); it must stay bit-identical to the real one."""
    from repro.core.hashing import probe_slot
    from repro.kernels.compact.ref import _probe_slot

    home = jnp.asarray(np.arange(0, 512, 7, dtype=np.int32) % 256)
    for step in (0, 1, 5, 31):
        np.testing.assert_array_equal(
            np.asarray(_probe_slot(home, jnp.int32(step), 256)),
            np.asarray(probe_slot(home, jnp.int32(step), 256)),
        )


def test_probe_place_overflow_is_flagged():
    """Chains capped below what placement needs: both impls agree on the
    overflow verdict (the signal that makes the caller grow further)."""
    from repro.core.hashing import hash_vertex

    keys = jnp.asarray(np.arange(40, dtype=np.int32))
    home = hash_vertex(keys, 32)
    active = jnp.ones(40, bool)
    _, o_ref = probe_place_reference(home, active, capacity=32, max_probes=2)
    _, o_got = probe_place(home, active, capacity=32, max_probes=2, impl="kernel_interpret")
    assert bool(o_ref) and bool(o_got)
