"""Serving engine: continuous batching correctness, slot reuse, page
accounting, deterministic failover."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import LM
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen2-7b")
    params = LM(cfg).init(jax.random.key(0))
    return cfg, params


def _free_running(cfg, params, prompt, n_new):
    """Reference: single-sequence incremental decode, greedy."""
    model = LM(cfg)
    cache = model.decode_init(1, 64, params=params)
    toks, gen = list(prompt), []
    for t in range(len(prompt) + n_new - 1):
        cur = toks[t] if t < len(toks) else gen[-1]
        logits, cache = model.decode_step(
            params, np.asarray([[cur]], np.int32), cache
        )
        if t >= len(prompt) - 1:
            gen.append(int(np.argmax(np.asarray(logits)[0, -1, : cfg.vocab])))
    return gen


def test_engine_matches_free_running_decode(qwen):
    cfg, params = qwen
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 7).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    eng.submit(Request(id=0, prompt=prompt, max_new_tokens=5))
    out = eng.run()[0].generated
    assert out == _free_running(cfg, params, prompt, 5)


def test_slot_reuse_is_isolated(qwen):
    """Two waves through the same slots: wave-2 results must equal a fresh
    engine's (no leakage from the previous occupant's KV rows)."""
    cfg, params = qwen
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 9))).astype(np.int32)
               for _ in range(6)]

    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p, max_new_tokens=4))
    done = eng.run()

    for i, p in enumerate(prompts):
        fresh = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
        fresh.submit(Request(id=0, prompt=p, max_new_tokens=4))
        assert done[i].generated == fresh.run()[0].generated, f"req {i} leaked"


def test_batching_matches_single(qwen):
    """Concurrent requests in different slots decode as if alone (attention
    is per-slot; forced-token prefill does not cross-contaminate)."""
    cfg, params = qwen
    rng = np.random.default_rng(5)
    p1 = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    eng.submit(Request(id=0, prompt=p1, max_new_tokens=4))
    eng.submit(Request(id=1, prompt=p2, max_new_tokens=4))
    done = eng.run()
    assert done[0].generated == _free_running(cfg, params, p1, 4)
    assert done[1].generated == _free_running(cfg, params, p2, 4)


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "rwkv6-3b", "musicgen-medium"])
def test_engine_drains_other_families(arch):
    cfg = get_smoke_config(arch)
    params = LM(cfg).init(jax.random.key(1))
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    rng = np.random.default_rng(0)
    for i in range(3):
        plen = int(rng.integers(3, 8))
        shape = (plen,) if cfg.n_codebooks == 1 else (plen, cfg.n_codebooks)
        eng.submit(Request(
            id=i, prompt=rng.integers(0, cfg.vocab, shape).astype(np.int32),
            max_new_tokens=3,
        ))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done.values())


def test_page_accounting_no_leaks(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    rng = np.random.default_rng(6)
    for i in range(8):
        eng.submit(Request(
            id=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(3, 10))).astype(np.int32),
            max_new_tokens=4,
        ))
    eng.run()
    assert len(eng.pages.free) == eng.pages.num_pages  # all pages returned
    assert eng.pages.seq_pages == {}


def test_failover_replay_identical(qwen):
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_batch=2, max_len=64, page_size=8)
    rng = np.random.default_rng(7)
    for i in range(5):
        eng.submit(Request(
            id=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(3, 10))).astype(np.int32),
            max_new_tokens=3,
        ))
    # fail over MID-FLIGHT (after some ticks, with live sequences)
    for _ in range(4):
        eng.tick()
    twin = eng.pages.replay()
    assert twin.seq_pages == eng.pages.seq_pages
    assert sorted(twin.free) == sorted(eng.pages.free)
    # graph states agree too (the abstract (V, E) sets)
    assert twin.graph.snapshot() == eng.pages.graph.snapshot()


def test_page_ownership_via_graph(qwen):
    """ContainsEdge validates ownership (paper op as production check)."""
    cfg, params = qwen
    eng = ServingEngine(cfg, params, max_batch=1, max_len=64, page_size=8)
    eng.submit(Request(id=0, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=3))
    eng.tick()
    pages = eng.pages.seq_pages[0]
    assert pages and all(eng.pages.owns(0, p) for p in pages)
    eng.run()
    assert not eng.pages.owns(0, pages[0])  # released on completion
