"""Unit + integration tests for the wait-free graph engine (paper core)."""

import numpy as np
import pytest

from repro.core import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_NOP,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    WaitFreeGraph,
    make_batch,
    make_state,
    run_sequential,
)
from repro.core import baselines, engine, fastpath
from repro.core.oracle import SequentialGraph
from repro.core.workloads import MIXES, initial_vertices, sample_batch

ENGINES = {
    "waitfree": engine.apply_batch,
    "fpsp": fastpath.apply_batch_fpsp,
    "lockfree": baselines.apply_lockfree,
    "serial": baselines.apply_serial,
    "coarse": baselines.apply_coarse,
}


def _check(variant_fn, seq, state=None, oracle=None):
    o, u, v = zip(*seq)
    batch = make_batch(o, u, v)
    state = state if state is not None else make_state(128, 128)
    res = variant_fn(state, batch)
    assert bool(res.ok)
    exp, _ = run_sequential(o, u, v, graph=oracle)
    assert np.asarray(res.success).tolist() == exp
    return res.state


@pytest.mark.parametrize("name", list(ENGINES))
def test_figure3_interleaving(name):
    """The paper's Fig. 3 subtlety: edge ops must observe endpoint liveness
    at their own linearization point, and stale edges never resurrect."""
    seq = [
        (OP_ADD_VERTEX, 5, 0),
        (OP_ADD_VERTEX, 7, 0),
        (OP_ADD_EDGE, 5, 7),
        (OP_CONTAINS_EDGE, 5, 7),
        (OP_REMOVE_VERTEX, 5, 0),
        (OP_CONTAINS_EDGE, 5, 7),
        (OP_ADD_VERTEX, 5, 0),
        (OP_CONTAINS_EDGE, 5, 7),   # must FAIL: stale binding
        (OP_ADD_EDGE, 5, 7),
        (OP_CONTAINS_EDGE, 5, 7),
    ]
    _check(ENGINES[name], seq)


@pytest.mark.parametrize("name", list(ENGINES))
def test_edge_requires_both_vertices(name):
    seq = [
        (OP_ADD_EDGE, 1, 2),       # F: neither vertex
        (OP_ADD_VERTEX, 1, 0),
        (OP_ADD_EDGE, 1, 2),       # F: v absent
        (OP_ADD_VERTEX, 2, 0),
        (OP_ADD_EDGE, 1, 2),       # T
        (OP_ADD_EDGE, 1, 2),       # F: duplicate
        (OP_REMOVE_EDGE, 1, 2),    # T
        (OP_REMOVE_EDGE, 1, 2),    # F
        (OP_CONTAINS_EDGE, 1, 2),  # F
    ]
    _check(ENGINES[name], seq)


@pytest.mark.parametrize("name", list(ENGINES))
def test_self_loops(name):
    seq = [
        (OP_ADD_VERTEX, 3, 0),
        (OP_ADD_EDGE, 3, 3),
        (OP_CONTAINS_EDGE, 3, 3),
        (OP_REMOVE_VERTEX, 3, 0),
        (OP_ADD_VERTEX, 3, 0),
        (OP_CONTAINS_EDGE, 3, 3),  # stale self-loop must be gone
    ]
    _check(ENGINES[name], seq)


@pytest.mark.parametrize("name", list(ENGINES))
def test_nop_ops(name):
    seq = [(OP_NOP, 0, 0), (OP_ADD_VERTEX, 1, 0), (OP_NOP, 9, 9)]
    o, u, v = zip(*seq)
    batch = make_batch(o, u, v)
    res = ENGINES[name](make_state(64, 64), batch)
    assert np.asarray(res.success).tolist() == [False, True, False]


@pytest.mark.parametrize("name", list(ENGINES))
@pytest.mark.parametrize("mix", list(MIXES))
def test_random_stress_matches_oracle(name, mix):
    """Cross-batch stress at brutal contention (key space 8)."""
    rng = np.random.default_rng(hash((name, mix)) % 2**32)
    state = make_state(256, 1024)
    oracle = SequentialGraph()
    phase = 0
    n_batches = 2 if name == "coarse" else 5
    for _ in range(n_batches):
        ops, us, vs = sample_batch(rng, 96, mix, key_space=8)
        batch = make_batch(ops, us, vs, phase_base=phase)
        phase += len(ops)
        res = ENGINES[name](state, batch)
        assert bool(res.ok)
        exp, oracle = run_sequential(ops, us, vs, graph=oracle)
        assert np.asarray(res.success).tolist() == exp
        state = res.state


def test_extreme_contention_single_key():
    """All n ops on one vertex key: the wait-free engine resolves the whole
    group in ONE pass (per-key contention does not change its step count)."""
    n = 257
    ops = np.where(np.arange(n) % 2 == 0, OP_ADD_VERTEX, OP_REMOVE_VERTEX).astype(np.int32)
    us = np.zeros(n, np.int32)
    batch = make_batch(ops, us)
    res = engine.apply_batch(make_state(64, 64), batch)
    exp, _ = run_sequential(ops, us, np.zeros(n, np.int32))
    assert np.asarray(res.success).tolist() == exp


def test_lockfree_rounds_grow_with_contention():
    """Lock-freedom has no per-op bound: retry rounds scale with the longest
    per-key conflict chain, while the wait-free engine is single-pass."""
    n = 64
    # all ops hit the same key -> lockfree needs ~n rounds
    ops = np.full(n, OP_CONTAINS_VERTEX, np.int32)
    us = np.zeros(n, np.int32)
    res_hot = baselines.apply_lockfree(make_state(64, 64), make_batch(ops, us))
    # distinct keys -> one round
    us2 = np.arange(n, dtype=np.int32)
    res_cold = baselines.apply_lockfree(make_state(256, 64), make_batch(ops, us2))
    hot_rounds = int(res_hot.stats[0])
    cold_rounds = int(res_cold.stats[0])
    # bucketed conflict detection gives a few spurious collisions when keys
    # are distinct, but rounds must stay near-constant; under single-key
    # contention they scale with the chain length (no per-op bound).
    assert cold_rounds <= 8
    assert hot_rounds >= n // 2
    assert hot_rounds > 4 * cold_rounds


def test_fpsp_fastpath_detects_conflicts():
    """FPSP stats: conflict count is 0 for disjoint batches, >0 when keys
    collide (the MAX_FAIL analogue)."""
    n = 32
    ops = np.full(n, OP_ADD_VERTEX, np.int32)
    us = np.arange(n, dtype=np.int32)
    res = fastpath.apply_batch_fpsp(make_state(256, 64), make_batch(ops, us))
    assert int(res.stats[0]) == 0  # all fast
    us_hot = np.zeros(n, np.int32)
    res = fastpath.apply_batch_fpsp(make_state(256, 64), make_batch(ops, us_hot))
    assert int(res.stats[0]) == n  # all conflicted -> slow path


class TestUnboundedGrowth:
    @pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
    def test_growth_preserves_semantics(self, mode):
        g = WaitFreeGraph(v_capacity=64, e_capacity=64, mode=mode)
        oracle = SequentialGraph()
        rng = np.random.default_rng(7)
        ops, us, vs = initial_vertices(1000)  # paper's initial graph
        got = g.apply(ops, us, vs)
        exp, oracle = run_sequential(ops, us, vs, graph=oracle)
        assert got.tolist() == exp
        for _ in range(4):
            ops, us, vs = sample_batch(rng, 512, "update", key_space=3000)
            got = g.apply(ops, us, vs)
            exp, oracle = run_sequential(ops, us, vs, graph=oracle)
            assert got.tolist() == exp
        V, E = g.snapshot()
        assert V == oracle.vertices
        assert E == oracle.edges
        assert g.state.v_capacity > 64  # growth actually happened

    def test_rehash_drops_stale_edges(self):
        g = WaitFreeGraph(v_capacity=64, e_capacity=64)
        assert g.add_vertex(1) and g.add_vertex(2) and g.add_edge(1, 2)
        assert g.remove_vertex(1)
        # force growth: stale edge (1,2) must be dropped, not revived
        ops, us, vs = initial_vertices(200)
        g.apply(ops, us, vs)
        assert g.contains_vertex(1)  # re-added by initial_vertices
        assert not g.contains_edge(1, 2)
        V, E = g.snapshot()
        assert (1, 2) not in E


class TestRehashPhysicalDeletion:
    """Growth must behave like Harris physical deletion: after a rehash the
    new tables hold exactly the live vertices and the incarnation-valid live
    edges — no tombstones, no stale bindings."""

    @staticmethod
    def _physical_invariants(state):
        from repro.core.types import EMPTY_KEY

        v_key = np.asarray(state.v_key)
        v_live = np.asarray(state.v_live)
        v_inc = np.asarray(state.v_inc)
        # every occupied vertex slot is live (no tombstones survive rehash)
        occupied = v_key != EMPTY_KEY
        assert (v_live == occupied).all()
        inc_of = {int(k): int(i) for k, i in zip(v_key[occupied], v_inc[occupied])}
        e_ku = np.asarray(state.e_key_u)
        e_kv = np.asarray(state.e_key_v)
        e_live = np.asarray(state.e_live)
        e_bu = np.asarray(state.e_inc_u)
        e_bv = np.asarray(state.e_inc_v)
        e_occ = e_ku != EMPTY_KEY
        # every occupied edge slot is live and bound to both endpoints'
        # *current* incarnations (no stale edges survive rehash)
        assert (e_live == e_occ).all()
        for u, v, bu, bv in zip(e_ku[e_occ], e_kv[e_occ], e_bu[e_occ], e_bv[e_occ]):
            assert inc_of.get(int(u)) == int(bu)
            assert inc_of.get(int(v)) == int(bv)

    @pytest.mark.parametrize("mode", ["waitfree", "fpsp"])
    def test_repeated_doubling_through_apply(self, mode):
        """Force ≥2 table doublings via apply; oracle equivalence holds at
        every step and the rehashed tables are physically compacted."""
        from repro.core.graph import _rehash

        g = WaitFreeGraph(v_capacity=64, e_capacity=64, mode=mode)
        oracle = SequentialGraph()
        rng = np.random.default_rng(31)
        phase_caps = [(g.state.v_capacity, g.state.e_capacity)]
        for wave in range(4):
            lo = 100 * wave
            keys = np.arange(lo, lo + 100, dtype=np.int32)
            ops = np.full(100, OP_ADD_VERTEX, np.int32)
            got = g.apply(ops, keys, np.zeros(100, np.int32))
            exp, oracle = run_sequential(ops, keys, np.zeros(100, np.int32), graph=oracle)
            assert got.tolist() == exp
            # tombstones: kill a third of this wave's keys
            kill = keys[rng.choice(100, 33, replace=False)]
            ops = np.full(33, OP_REMOVE_VERTEX, np.int32)
            got = g.apply(ops, kill, np.zeros(33, np.int32))
            exp, oracle = run_sequential(ops, kill, np.zeros(33, np.int32), graph=oracle)
            assert got.tolist() == exp
            # edges across the live range, some of which will go stale later
            eu = rng.integers(lo, lo + 100, 80).astype(np.int32)
            ev = rng.integers(0, lo + 100, 80).astype(np.int32)
            ops = np.full(80, OP_ADD_EDGE, np.int32)
            got = g.apply(ops, eu, ev)
            exp, oracle = run_sequential(ops, eu, ev, graph=oracle)
            assert got.tolist() == exp
            phase_caps.append((g.state.v_capacity, g.state.e_capacity))
        assert g.state.v_capacity >= 64 * 4, phase_caps  # ≥2 doublings
        assert g.snapshot() == (oracle.vertices, oracle.edges)
        # a rehash at current capacity is a pure compaction: the abstract
        # graph is unchanged and the physical tables are clean
        compacted = _rehash(g.state, g.state.v_capacity, g.state.e_capacity)
        self._physical_invariants(compacted)
        g2 = WaitFreeGraph(mode=mode)
        g2.state = compacted
        assert g2.snapshot() == (oracle.vertices, oracle.edges)

    def test_rehash_drops_tombstones_and_stale_edges(self):
        """Direct check: tombstoned vertices and stale-incarnation edges are
        physically absent after _rehash, while the abstract graph survives."""
        from repro.core.graph import _rehash
        from repro.core.types import EMPTY_KEY

        g = WaitFreeGraph(v_capacity=64, e_capacity=64)
        oracle = SequentialGraph()
        seq = [(OP_ADD_VERTEX, k, 0) for k in range(10)]
        seq += [(OP_ADD_EDGE, k, k + 1) for k in range(9)]
        seq += [(OP_REMOVE_VERTEX, 4, 0)]          # tombstone + 2 stale edges
        seq += [(OP_REMOVE_VERTEX, 7, 0), (OP_ADD_VERTEX, 7, 0)]  # churn
        o, u, v = (np.asarray(c, np.int32) for c in zip(*seq))
        got = g.apply(o, u, v)
        exp, oracle = run_sequential(o, u, v, graph=oracle)
        assert got.tolist() == exp

        pre_used = int((np.asarray(g.state.v_key) != EMPTY_KEY).sum())
        assert pre_used == 10  # 9 live + 1 tombstone (key 4)
        snap_before = g.snapshot()
        new_state = _rehash(g.state, g.state.v_capacity, g.state.e_capacity)
        self._physical_invariants(new_state)
        # tombstone physically dropped: only the 9 live keys remain
        assert int((np.asarray(new_state.v_key) != EMPTY_KEY).sum()) == 9
        # stale edges (3-4, 4-5 via removal; 6-7, 7-8 via churn) dropped
        assert int((np.asarray(new_state.e_key_u) != EMPTY_KEY).sum()) == 5
        g.state = new_state  # setter invalidates the cached traversal snapshot
        assert g.snapshot() == snap_before == (oracle.vertices, oracle.edges)


def test_paper_api_sequence():
    """The six-method API behaves per the paper's sequential spec table."""
    g = WaitFreeGraph(64, 64)
    assert g.add_vertex(10)
    assert not g.add_vertex(10)
    assert g.contains_vertex(10)
    assert not g.contains_vertex(11)
    assert g.add_vertex(11)
    assert g.add_edge(10, 11)
    assert not g.add_edge(10, 11)
    assert g.contains_edge(10, 11)
    assert not g.contains_edge(11, 10)  # directed!
    assert g.remove_edge(10, 11)
    assert not g.remove_edge(10, 11)
    assert g.remove_vertex(10)
    assert not g.remove_vertex(10)
    assert not g.contains_edge(10, 11)
