"""The paper's central property, restated for the dataflow machine:

wait-free = every published op completes in a bounded number of passes
*independent of contention*.  The wait-free engine is one pass by
construction; the lock-free baseline's rounds grow with the longest per-key
conflict chain; FPSP is bounded (fast pass + at most one slow pass).

These tests measure the *step structure*, not wall time, so they are exact
on any machine.
"""

import jax
import numpy as np
import pytest

from repro.core import baselines, engine, fastpath
from repro.core.types import (
    OP_ADD_VERTEX, OP_CONTAINS_VERTEX, OP_REMOVE_VERTEX,
    make_batch, make_state,
)


def _hot_batch(n):
    """Adversarial: every op fights over one key."""
    ops = np.tile(
        np.array([OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_CONTAINS_VERTEX],
                 np.int32), n // 3 + 1
    )[:n]
    return make_batch(ops, np.zeros(n, np.int32))


def _cold_batch(n):
    ops = np.full(n, OP_ADD_VERTEX, np.int32)
    return make_batch(ops, np.arange(n, dtype=np.int32))


@pytest.mark.parametrize("n", [32, 128, 512])
def test_waitfree_single_pass_regardless_of_contention(n):
    """One apply_batch = one bounded pass; contention changes nothing about
    the op-count of the program (same jitted computation, no retry loop)."""
    st = make_state(2048, 2048)
    hot = jax.make_jaxpr(engine.apply_batch)(st, _hot_batch(n))
    cold = jax.make_jaxpr(engine.apply_batch)(st, _cold_batch(n))
    # identical program structure: number of primitive eqns does not depend
    # on the key distribution (only on n) — the wait-free bound is static.
    assert len(hot.eqns) == len(cold.eqns)
    # and no unbounded retry construct driven by data: while loops in the
    # engine are bounded-probe loops only (trip count <= probe cap).
    res_hot = engine.apply_batch(st, _hot_batch(n))
    res_cold = engine.apply_batch(st, _cold_batch(n))
    assert bool(res_hot.ok) and bool(res_cold.ok)


@pytest.mark.parametrize("n", [24, 96, 384])
def test_lockfree_rounds_scale_with_chain(n):
    st = make_state(2048, 2048)
    hot_rounds = int(baselines.apply_lockfree(st, _hot_batch(n)).stats[0])
    cold_rounds = int(baselines.apply_lockfree(st, _cold_batch(n)).stats[0])
    assert hot_rounds >= n // 3          # no per-op bound under contention
    assert cold_rounds <= 8              # near-constant when disjoint


def test_fpsp_bounded_two_phases():
    """FPSP = fast pass + at most one slow pass — measured via its stats:
    the conflicted count equals the ops routed to the (single) slow pass."""
    n = 300
    st = make_state(2048, 2048)
    mixed_ops = np.full(n, OP_ADD_VERTEX, np.int32)
    us = np.concatenate([
        np.zeros(n // 2, np.int32),            # contended half
        1 + np.arange(n - n // 2, dtype=np.int32),  # disjoint half
    ])
    res = fastpath.apply_batch_fpsp(st, make_batch(mixed_ops, us))
    assert int(res.stats[0]) == n // 2     # only the contended half is slow
    assert bool(res.ok)


def test_helping_equivalence_hot_vs_cold_results():
    """Helping (phase order) resolves contention exactly like the sequential
    spec: first add wins, removes/contains see phase-ordered liveness."""
    from repro.core.oracle import run_sequential

    n = 90
    batch = _hot_batch(n)
    res = engine.apply_batch(make_state(1024, 1024), batch)
    expected, _ = run_sequential(
        np.asarray(batch.op), np.asarray(batch.u), np.asarray(batch.v)
    )
    assert np.asarray(res.success).tolist() == expected
