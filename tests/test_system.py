"""System-level integration: train loop learns, serve consumes trained
params, step builders lower for every shape kind, run-dict knobs hold."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticTokenStream
from repro.launch.steps import build_prefill_step, build_train_step
from repro.launch.train import TrainRunner
from repro.models import LM
from repro.models.config import ArchConfig
from repro.serving import Request, ServingEngine

TINY = ArchConfig(
    name="sys-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, dtype="float32",
)


def test_train_loss_decreases():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    runner = TrainRunner(TINY, mesh, ckpt_dir=None, batch=8, seq=32)
    runner.init_or_restore()
    losses = runner.train(30, log_every=5, save_every=0, log=lambda *a: None)
    first, last = losses[0][1], losses[-1][1]
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, (first, last)


def test_train_then_serve():
    """The whole lifecycle: train params, hand them to the serving engine."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    runner = TrainRunner(TINY, mesh, ckpt_dir=None, batch=4, seq=32)
    runner.init_or_restore()
    runner.train(3, log_every=10, save_every=0, log=lambda *a: None)

    eng = ServingEngine(TINY, runner.params, max_batch=2, max_len=48,
                        page_size=8)
    eng.submit(Request(id=0, prompt=np.arange(1, 6, dtype=np.int32),
                       max_new_tokens=4))
    done = eng.run()
    assert len(done[0].generated) == 4
    assert all(0 <= t < TINY.vocab for t in done[0].generated)


def test_prefill_matches_train_forward_logits():
    """prefill_step's last-token logits == hidden_states+logits directly."""
    cfg = get_smoke_config("qwen2-7b")
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    prefill, _, run = build_prefill_step(cfg, multi_pod=False)
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)), jnp.int32
    )
    with jax.make_mesh((1, 1), ("data", "model")):
        out = prefill(params, {"tokens": toks})
        hid, _, _ = model.hidden_states(params, toks, run=run)
        ref = model._logits(params, hid[:, -1:])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("knobs", [
    {"attn_seq_shard": False, "attn_block_q": 512},
    {"attn_seq_shard": True, "attn_block_q": 4096},
])
def test_run_knobs_numerically_equivalent(knobs):
    """The §Perf layout knobs change sharding, never math (1-device check)."""
    cfg = get_smoke_config("qwen2-7b")
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (2, 32)), jnp.int32
    )
    base_run = {"sp": True, "remat": False, "dp_axes": ("data",),
                "attn_impl": "chunked", "loss_chunk": 512}
    with jax.make_mesh((1, 1), ("data", "model")):
        ref, _, _ = model.hidden_states(params, toks, run=base_run)
        got, _, _ = model.hidden_states(params, toks, run={**base_run, **knobs})
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-4, atol=2e-4)


def test_data_pipeline_batch_shapes_and_determinism():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=3)
    a = SyntheticTokenStream(cfg).next_batch()
    b = SyntheticTokenStream(cfg).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["targets"].shape == (4, 16)
    # targets are tokens shifted by one within the same row stream
    assert (a["tokens"][:, 1:] == a["targets"][:, :-1]).all()


def test_accum_equals_no_accum():
    """Gradient accumulation (the HBM-fitting device for big train cells)
    must not change the update."""
    cfg = TINY
    model = LM(cfg)
    params = model.init(jax.random.key(2))
    from repro.optim import adamw_init

    rng = np.random.default_rng(2)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
        "mask": jnp.ones((8, 16), jnp.float32),
    }
    outs = []
    with jax.make_mesh((1, 1), ("data", "model")):
        for accum in (1, 4):
            step, _, _ = build_train_step(cfg, multi_pod=False, accum=accum)
            opt = adamw_init(params)
            p2, _, metrics = jax.jit(step)(params, opt, batch)
            outs.append((p2, float(metrics["loss"])))
    assert abs(outs[0][1] - outs[1][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[0][0]), jax.tree.leaves(outs[1][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)
