"""AdamW + schedule + clipping semantics."""

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import cosine_schedule, opt_pspecs


def _cfg(**kw):
    base = dict(lr=0.1, warmup_steps=2, total_steps=10_000, weight_decay=0.0,
                clip_norm=1e9, grad_dtype=None)
    base.update(kw)
    return AdamWConfig(**base)


def test_quadratic_converges():
    cfg = _cfg()
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2.0 * params["w"]}   # d/dw of w^2
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_weight_decay_pulls_to_zero():
    cfg = _cfg(weight_decay=0.5)
    params = {"w": jnp.asarray([1.0])}
    state = adamw_init(params)
    for _ in range(50):
        params, state, _ = adamw_update(cfg, params, {"w": jnp.zeros(1)}, state)
    assert float(params["w"][0]) < 0.9  # decays even with zero gradient


def test_clip_norm_caps_update():
    cfg = _cfg(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, _, m1 = adamw_update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert float(m1["grad_norm"]) > 1e5  # reported pre-clip norm


def test_master_weights_survive_bf16_params():
    """bf16 params accumulate tiny updates through the f32 master copy."""
    cfg = _cfg(lr=1e-4)
    params = {"w": jnp.ones(1, jnp.bfloat16)}
    state = adamw_init(params)
    for _ in range(10):
        params, state, _ = adamw_update(
            cfg, params, {"w": jnp.ones(1, jnp.float32)}, state
        )
    # master moved even if bf16 rounding would have eaten single steps
    assert float(state["master"]["w"][0]) < 1.0
    assert params["w"].dtype == jnp.bfloat16


def test_master_does_not_alias_params():
    params = {"w": jnp.ones(4, jnp.float32)}
    state = adamw_init(params)
    # donation-safety: distinct buffers (regression: f32 astype aliased)
    assert state["master"]["w"].unsafe_buffer_pointer() != params["w"].unsafe_buffer_pointer()


def test_schedule_warmup_and_decay():
    cfg = _cfg(lr=1.0, warmup_steps=10, total_steps=110)
    lr0 = float(cosine_schedule(cfg, jnp.int32(1)))
    lr_w = float(cosine_schedule(cfg, jnp.int32(10)))
    lr_end = float(cosine_schedule(cfg, jnp.int32(110)))
    assert lr0 < 0.2 and abs(lr_w - 1.0) < 1e-6 and lr_end < 1e-3


def test_opt_pspecs_mirror_params():
    from jax.sharding import PartitionSpec as P

    pspecs = {"a": P("data", None), "b": {"c": P(None, "model")}}
    out = opt_pspecs(pspecs)
    assert out["m"] == pspecs and out["v"] == pspecs and out["master"] == pspecs
    assert out["count"] == P()
