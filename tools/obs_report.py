"""Render an obs dump (``repro-obs/1`` JSON) as a human-readable report.

Accepts either shape (``docs/OBSERVABILITY.md``):

* a single registry dump — the dict ``Registry.dump()`` returns (what
  ``examples/reachability.py`` prints, or a file you wrote yourself);
* a benchmark bundle — ``BENCH_obs.json`` from
  ``benchmarks/graph_reachability.py``, with per-graph dumps under
  ``"graphs"``.

For each registry it prints the counters, gauges, histograms (with an
ASCII bar per value — they are exact integer histograms, so every value
is a row), span timings, and the bounded event log, plus the derived
``fastpath_frac`` summary when the FPSP counters are present.

Usage:
    python tools/obs_report.py BENCH_obs.json
    python tools/obs_report.py dump.json --section histograms
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BAR_WIDTH = 40
SECTIONS = ("counters", "gauges", "histograms", "samples", "spans", "events")


def _bar(count: int, peak: int) -> str:
    n = max(1, round(BAR_WIDTH * count / peak)) if peak else 0
    return "#" * n


def _fastpath_frac(counters) -> float | None:
    # mirror of repro.obs.metrics.fastpath_frac, kept dependency-free so the
    # report runs on a bare artifact download (no PYTHONPATH=src needed)
    ops = counters.get("fastpath.ops", 0)
    if ops:
        return 1.0 - counters.get("fastpath.conflicted", 0) / ops
    eops = counters.get("fastpath.eops", 0)
    if eops:
        return 1.0 - counters.get("fastpath.edge_dup", 0) / eops
    return None


def render_registry(dump: dict, *, section: str | None = None,
                    out=sys.stdout) -> None:
    if not dump.get("enabled", True):
        print("  (registry disabled — no data)", file=out)
        return

    def want(name: str) -> bool:
        return section is None or section == name

    counters = dump.get("counters", {})
    if want("counters") and counters:
        print("  counters:", file=out)
        width = max(len(k) for k in counters)
        for k, v in counters.items():
            print(f"    {k:<{width}}  {v}", file=out)
        ff = _fastpath_frac(counters)
        if ff is not None:
            print(f"    {'-> fastpath_frac':<{width}}  {ff:.4f}", file=out)

    gauges = dump.get("gauges", {})
    if want("gauges") and gauges:
        print("  gauges:", file=out)
        for k, v in gauges.items():
            print(f"    {k}  {v:.4g}", file=out)

    hists = dump.get("histograms", {})
    if want("histograms") and hists:
        print("  histograms:", file=out)
        for name, h in hists.items():
            print(f"    {name}  (n={h['count']} mean={h['mean']:.2f} "
                  f"p50={h['p50']} p99={h['p99']} max={h['max']})", file=out)
            counts = {int(k): v for k, v in h.get("counts", {}).items()}
            peak = max(counts.values(), default=0)
            for val in sorted(counts):
                print(f"      {val:>6}  {counts[val]:>8}  "
                      f"{_bar(counts[val], peak)}", file=out)

    for part in ("samples", "spans"):
        series = dump.get(part, {})
        if want(part) and series:
            print(f"  {part}:", file=out)
            for name, s in series.items():
                print(f"    {name}  n={s['count']} total={s['total_ms']:.2f}ms "
                      f"mean={s['mean_ms']:.3f}ms p50={s['p50_ms']:.3f}ms "
                      f"p99={s['p99_ms']:.3f}ms max={s['max_ms']:.3f}ms",
                      file=out)

    events = dump.get("events", [])
    if want("events") and events:
        print(f"  events ({len(events)}"
              + (f", {dump['dropped_events']} dropped" if
                 dump.get("dropped_events") else "") + "):", file=out)
        for ev in events:
            fields = " ".join(f"{k}={v}" for k, v in ev.items() if k != "event")
            print(f"    {ev.get('event', '?')}  {fields}", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", type=Path, help="registry dump or BENCH_obs.json")
    ap.add_argument("--section", choices=SECTIONS, default=None,
                    help="print only one section")
    args = ap.parse_args(argv)

    try:
        data = json.loads(args.path.read_text())
    except (OSError, ValueError) as e:
        print(f"::error::unreadable obs dump ({args.path}: {e})")
        return 1

    if "graphs" in data:  # benchmark bundle
        meta = {k: v for k, v in data.items() if k != "graphs"}
        print(f"# {meta.get('bench', args.path.name)} "
              f"(backend={meta.get('backend', '?')}, "
              f"quick={meta.get('quick', '?')})")
        for label, dump in data["graphs"].items():
            print(f"\n== {label} ==")
            render_registry(dump, section=args.section)
    elif data.get("schema", "").startswith("repro-obs/"):
        render_registry(data, section=args.section)
    else:
        print(f"::error::{args.path}: neither a repro-obs dump nor a "
              f"BENCH_obs.json bundle")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
