"""Docs cross-link check: every relative markdown link must resolve.

Run from the repo root (CI lint job does):

    python tools/check_docs_links.py

Scans README.md, ROADMAP.md, CHANGES.md, and docs/*.md for markdown link
targets ``[text](target)``
and fails if a relative target (no URL scheme, not a pure anchor) does not
exist on disk, or escapes the repository (the CI badge URL is the one
sanctioned escape — GitHub resolves it, the filesystem cannot).  Also
enforces the two structural links this repo promises: README must point at
both docs/ARCHITECTURE.md and docs/KERNELS.md.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\]\(([^)\s]+)\)")
SCHEMES = ("http://", "https://", "mailto:")

REQUIRED_IN_README = (
    "docs/ARCHITECTURE.md",
    "docs/KERNELS.md",
    "docs/OBSERVABILITY.md",
)


def check_file(md: Path) -> list[str]:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(SCHEMES) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.is_relative_to(ROOT):
            # the only sanctioned escape is the CI badge (GitHub resolves
            # `../../actions/...` server-side); any other out-of-repo
            # relative link is a typo that would 404 on GitHub
            if "/actions/" not in target:
                errors.append(
                    f"{md.relative_to(ROOT)}: link escapes the repo -> {target}"
                )
            continue
        if not resolved.exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return errors


def main() -> int:
    files = [
        ROOT / "README.md",
        ROOT / "ROADMAP.md",
        ROOT / "CHANGES.md",
        *sorted((ROOT / "docs").glob("*.md")),
    ]
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"missing file: {md.relative_to(ROOT)}")
            continue
        errors.extend(check_file(md))
    readme = (ROOT / "README.md").read_text()
    for required in REQUIRED_IN_README:
        if required not in readme:
            errors.append(f"README.md: missing required link to {required}")
    for err in errors:
        print(f"::error::{err}")
    if not errors:
        print(f"docs links OK ({len(files)} files checked)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
