"""Maintenance-benchmark regression gate.

Compares the current run's ``BENCH_maintenance.json`` against a baseline
file (the previous CI run's artifact) and fails on a >25% ``snap_ms``
slowdown in any **host-oracle** maintenance row — the deterministic numpy
paths (``delta_host``, ``rehash_host``) whose cost is dominated by
algorithmic work, not device dispatch, so a sustained slowdown there is a
real complexity regression rather than scheduler noise.  Device/interpret
rows are reported but never gate: their timings swing with XLA version and
CI machine load.

Rows are keyed by ``(impl, build, graph_size, batch, n_shards)``; keys
present in only one file are reported and skipped (the benchmark matrix is
allowed to evolve).  A missing or unreadable baseline exits 0 — the first
run after this gate lands, a matrix change, or an expired artifact must
not block CI.

Usage:
    python tools/bench_regression.py BASELINE.json CURRENT.json \
        [--threshold 0.25]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# the host-oracle rows: deterministic numpy work, meaningful to gate on
GATED_IMPLS = ("delta_host", "rehash_host")
# below this absolute cost, ratios are mostly timer noise on shared runners
MIN_GATED_MS = 0.25


def _load_rows(path: Path):
    data = json.loads(path.read_text())
    rows = data["rows"] if isinstance(data, dict) else data
    out = {}
    for r in rows:
        key = (
            r["impl"],
            r.get("build", "?"),
            r.get("graph_size", 0),
            r.get("batch", 0),
            r.get("n_shards", 1),
        )
        out[key] = float(r["snap_ms"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("current", type=Path)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional slowdown (default 0.25)")
    args = ap.parse_args(argv)

    try:
        base = _load_rows(args.baseline)
    except (OSError, ValueError, KeyError) as e:
        print(f"no usable baseline ({args.baseline}: {e}); skipping gate")
        return 0
    try:
        cur = _load_rows(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"::error::current bench file unreadable ({args.current}: {e})")
        return 1

    failures = []
    compared = 0
    for key in sorted(set(base) | set(cur)):
        impl = key[0]
        if key not in base or key not in cur:
            where = "baseline" if key not in base else "current"
            print(f"skip (only in {'current' if where == 'baseline' else 'baseline'}): {key}")
            continue
        b, c = base[key], cur[key]
        ratio = c / b if b > 0 else float("inf")
        gated = impl in GATED_IMPLS and max(b, c) >= MIN_GATED_MS
        tag = "GATE" if gated else "info"
        print(f"[{tag}] {key}: {b:.3f} ms -> {c:.3f} ms ({ratio:.2f}x)")
        if gated:
            compared += 1
            if ratio > 1.0 + args.threshold:
                failures.append((key, b, c, ratio))

    if not compared:
        print("no gated host-oracle rows in common; nothing to compare")
        return 0
    for key, b, c, ratio in failures:
        print(f"::error::maintenance regression {key}: "
              f"{b:.3f} ms -> {c:.3f} ms ({ratio:.2f}x > "
              f"{1 + args.threshold:.2f}x allowed)")
    if not failures:
        print(f"bench regression gate OK ({compared} host-oracle rows within "
              f"{args.threshold:.0%})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
