"""Maintenance-benchmark regression gate.

Compares the current run's ``BENCH_maintenance.json`` against a baseline
file (the previous CI run's artifact) and fails on:

* a >25% ``snap_ms`` (or amortized ``us_per_query``) slowdown in any
  **host-oracle** maintenance row — the deterministic numpy paths
  (``delta_host``, ``rehash_host``) whose cost is dominated by algorithmic
  work, not device dispatch, so a sustained slowdown there is a real
  complexity regression rather than scheduler noise.  Device/interpret
  rows are reported but never gate: their timings swing with XLA version
  and CI machine load.
* a >0.10 **absolute** drop in ``fastpath_frac`` — the obs-derived
  fraction of build ops the FPSP engine resolved on its fast (sort-free)
  lane (``docs/OBSERVABILITY.md``).  The build streams are seeded, so this
  column is deterministic per row; a drop means the conflict mask got
  pessimistic (ops needlessly demoted to the slow path), which is a
  functional regression the timing columns can hide on fast machines.

Rows are keyed by ``(impl, build, graph_size, batch, n_shards)``; keys
present in only one file are reported and skipped (the benchmark matrix is
allowed to evolve), and rows whose baseline predates a column (e.g.
``fastpath_frac`` before the obs PR) skip that column's gate.  A missing
or unreadable baseline exits 0 — the first run after this gate lands, a
matrix change, or an expired artifact must not block CI.

Usage:
    python tools/bench_regression.py BASELINE.json CURRENT.json \
        [--threshold 0.25] [--fastpath-drop 0.10]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# the host-oracle rows: deterministic numpy work, meaningful to gate on
GATED_IMPLS = ("delta_host", "rehash_host")
# below these absolute costs, ratios are mostly timer noise on shared runners
MIN_GATED_MS = 0.25
MIN_GATED_US = 1.0


def _load_rows(path: Path):
    data = json.loads(path.read_text())
    rows = data["rows"] if isinstance(data, dict) else data
    out = {}
    for r in rows:
        key = (
            r["impl"],
            r.get("build", "?"),
            r.get("graph_size", 0),
            r.get("batch", 0),
            r.get("n_shards", 1),
        )
        out[key] = r
    return out


def _ratio_gate(key, base_row, cur_row, field, floor, threshold, failures):
    """Slowdown gate on one timing column; returns 1 if the row gated."""
    b = base_row.get(field)
    c = cur_row.get(field)
    if b is None or c is None:
        return 0
    b, c = float(b), float(c)
    ratio = c / b if b > 0 else float("inf")
    gated = key[0] in GATED_IMPLS and max(b, c) >= floor
    tag = "GATE" if gated else "info"
    print(f"[{tag}] {key} {field}: {b:.3f} -> {c:.3f} ({ratio:.2f}x)")
    if gated and ratio > 1.0 + threshold:
        failures.append(
            (key, field, f"{b:.3f} -> {c:.3f} ({ratio:.2f}x > "
             f"{1 + threshold:.2f}x allowed)")
        )
    return 1 if gated else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("current", type=Path)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional slowdown (default 0.25)")
    ap.add_argument("--fastpath-drop", type=float, default=0.10,
                    help="max tolerated absolute fastpath_frac drop "
                         "(default 0.10)")
    args = ap.parse_args(argv)

    try:
        base = _load_rows(args.baseline)
    except (OSError, ValueError, KeyError) as e:
        print(f"no usable baseline ({args.baseline}: {e}); skipping gate")
        return 0
    try:
        cur = _load_rows(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"::error::current bench file unreadable ({args.current}: {e})")
        return 1

    failures = []
    compared = 0
    for key in sorted(set(base) | set(cur)):
        if key not in base or key not in cur:
            where = "current" if key not in base else "baseline"
            print(f"skip (only in {where}): {key}")
            continue
        br, cr = base[key], cur[key]
        compared += _ratio_gate(
            key, br, cr, "snap_ms", MIN_GATED_MS, args.threshold, failures
        )
        compared += _ratio_gate(
            key, br, cr, "us_per_query", MIN_GATED_US, args.threshold, failures
        )
        # fastpath_frac: absolute-drop gate, on every row that has it in
        # both files (None / absent — non-FPSP builds, pre-obs baselines —
        # skips the gate for that row)
        bf, cf = br.get("fastpath_frac"), cr.get("fastpath_frac")
        if bf is not None and cf is not None:
            compared += 1
            drop = float(bf) - float(cf)
            tag = "GATE"
            print(f"[{tag}] {key} fastpath_frac: {float(bf):.4f} -> "
                  f"{float(cf):.4f} (drop {drop:+.4f})")
            if drop > args.fastpath_drop:
                failures.append(
                    (key, "fastpath_frac",
                     f"{float(bf):.4f} -> {float(cf):.4f} (drop {drop:.4f} > "
                     f"{args.fastpath_drop:.2f} allowed)")
                )

    if not compared:
        print("no gated rows in common; nothing to compare")
        return 0
    for key, field, msg in failures:
        print(f"::error::bench regression {key} {field}: {msg}")
    if not failures:
        print(f"bench regression gate OK ({compared} gated comparisons within "
              f"bounds)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
