"""Paper Fig. 4 reproduction: throughput vs. concurrency, 3 mixes × 5 engines.

Paper setting: 56-core Xeon, threads ∈ {1,10,…,70}, initial graph of 1000
vertices, 20-second runs, 3 operation mixes.  Dataflow analogue: "threads"
are **lanes** — the number of ops published to the ODA per batch; each engine
resolves the batch with its own progress discipline:

  coarse    — one host→device round trip per op (global lock)
  serial    — one lax.scan step per op inside one jit (HoH / lazy locks)
  lockfree  — optimistic rounds, min-phase wins, losers retry (Harris)
  waitfree  — single phase-ordered helping pass (the paper's algorithm)
  fpsp      — conflict-free ops bypass the scans (paper §3.4)

The paper's qualitative claims to reproduce (EXPERIMENTS.md §Fig4):
  * lock-free scales with concurrency; coarse/HoH do not;
  * wait-free alone trails lock-free (helping overhead — here: the
    unconditional sort+scan waves);
  * fast-path-slow-path recovers lock-free throughput while keeping the
    wait-free bound.

CPU caveat: one physical core executes the vector lanes, so absolute ops/s
compress; lane scaling measures *work-efficiency* of each engine's resolve
step, which is the machine-independent content of Fig. 4.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import baselines, engine, fastpath
from repro.core.types import make_batch, make_state
from repro.core.workloads import initial_vertices, sample_batch

ENGINES = {
    "coarse": baselines.apply_coarse,
    "serial": baselines.apply_serial,
    "lockfree": baselines.apply_lockfree,
    "waitfree": engine.apply_batch,
    "fpsp": fastpath.apply_batch_fpsp,
}

LANES = (1, 8, 32, 128, 512)
# coarse pays one device round trip per lane; cap its sweep and say so.
COARSE_MAX_LANES = 128


def _prepare_state(key_space: int = 1000):
    st = make_state(4096, 16384)
    ops, us, vs = initial_vertices(key_space)
    res = engine.apply_batch(st, make_batch(ops, us, vs))
    assert bool(res.ok)
    return res.state


def run(
    mixes=("lookup", "balanced", "update"),
    lanes=LANES,
    engines=tuple(ENGINES),
    timed_batches: int = 8,
    seed: int = 0,
) -> List[Dict]:
    rows = []
    base = _prepare_state()
    for mix in mixes:
        rng = np.random.default_rng(seed)
        for n in lanes:
            batches = [
                make_batch(*sample_batch(rng, n, mix), phase_base=i * n)
                for i in range(timed_batches + 2)
            ]
            for name in engines:
                if name == "coarse" and n > COARSE_MAX_LANES:
                    print(f"# dropped: coarse @ {n} lanes (host-loop too slow; "
                          f"capped at {COARSE_MAX_LANES})")
                    continue
                fn = ENGINES[name]
                # warmup (compile)
                r = fn(base, batches[0])
                jax.block_until_ready(r.state)
                t0 = time.perf_counter()
                st = base
                for b in batches[2:]:
                    r = fn(st, b)
                    st = r.state
                jax.block_until_ready(st)
                dt = time.perf_counter() - t0
                ops_per_s = timed_batches * n / dt
                rows.append(
                    dict(mix=mix, engine=name, lanes=n, ops_per_s=ops_per_s,
                         us_per_op=1e6 * dt / (timed_batches * n))
                )
    return rows


def main(quick: bool = False):
    rows = run(
        lanes=(1, 32, 512) if quick else LANES,
        timed_batches=4 if quick else 8,
    )
    print("bench,mix,engine,lanes,us_per_op,ops_per_s")
    for r in rows:
        print(
            f"graph_throughput,{r['mix']},{r['engine']},{r['lanes']},"
            f"{r['us_per_op']:.2f},{r['ops_per_s']:.0f}"
        )
    return rows


if __name__ == "__main__":
    main()
