"""Serving-engine benchmark: wait-free paged KV vs. contiguous allocation.

Beyond-paper experiment (DESIGN.md §3): the paper's graph is the page-table
manager of the serving engine.  We drive both allocators with the same
randomized continuous-batching trace and report:

  * page-table update cost per serving step (the graph-engine op batch);
  * KV memory footprint: pages-in-use × page_size vs. contiguous
    max_len × slots (the vLLM argument, reproduced on the wait-free table);
  * sustained batch occupancy under a fixed page budget.

The trace is deterministic (seeded), so every host computes the identical
table — the multi-host coordination-free property the engine exists for.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.serving import PagedKVManager


def drive(
    num_pages: int = 512,
    page_size: int = 16,
    max_seqs: int = 64,
    steps: int = 200,
    seed: int = 0,
) -> Dict:
    rng = np.random.default_rng(seed)
    mgr = PagedKVManager(num_pages, page_size)
    active: Dict[int, List[int]] = {}  # seq -> [remaining_tokens]
    next_id = 0
    peak_pages = 0
    occupancy = []
    t_updates = 0.0
    max_len = 0
    reserved = 0  # pages promised to admitted-but-still-growing requests

    for _ in range(steps):
        admit = {}
        # admission control: reserve each request's whole-lifetime footprint
        # so growth can never hit an empty free list mid-decode
        while len(active) + len(admit) < max_seqs:
            prompt = int(rng.integers(8, 128))
            out = int(rng.integers(8, 64))
            need = -(-(prompt + out) // page_size)
            if len(mgr.free) - reserved < need:
                break
            reserved += need
            admit[next_id] = prompt
            active[next_id] = [out]   # decode steps remaining after prefill
            max_len = max(max_len, prompt + out)
            next_id += 1
            if rng.random() < 0.5:
                break
        extend, finish = [], []
        for seq in list(active):
            if seq in admit:
                continue
            active[seq][0] -= 1
            if active[seq][0] <= 0:
                finish.append(seq)
                del active[seq]
            else:
                extend.append(seq)
        t0 = time.perf_counter()
        before_free = len(mgr.free)
        new_pages = mgr.step_ops(admit, extend, finish)
        t_updates += time.perf_counter() - t0
        reserved -= sum(len(v) for v in new_pages.values())
        reserved = max(reserved, 0)
        in_use = num_pages - len(mgr.free)
        peak_pages = max(peak_pages, in_use)
        occupancy.append(len(active))

    paged_bytes = peak_pages * page_size
    contiguous_bytes = max_seqs * max_len
    return {
        "steps": steps,
        "us_per_step": 1e6 * t_updates / steps,
        "peak_pages": peak_pages,
        "paged_kv_tokens": paged_bytes,
        "contiguous_kv_tokens": contiguous_bytes,
        "memory_saving": 1.0 - paged_bytes / contiguous_bytes,
        "mean_occupancy": float(np.mean(occupancy)),
        "ops_applied": sum(len(o[0]) for o in mgr.op_log),
    }


def main(quick: bool = False):
    r = drive(steps=50 if quick else 200)
    print("bench,metric,value")
    for k in ("us_per_step", "peak_pages", "paged_kv_tokens",
              "contiguous_kv_tokens", "memory_saving", "mean_occupancy",
              "ops_applied"):
        print(f"serving_paged_kv,{k},{r[k]}")
    return r


if __name__ == "__main__":
    main()
