"""Attribute collective traffic to source ops: the §Perf profiler.

  PYTHONPATH=src:. python -m benchmarks.collective_sites results/hlo/<tag>.hlo.gz

For each collective op: exec-weighted bytes (trip-count multiplied through
the loop nest *summing over every call site* — remat clones share
computations, so max-propagation undercounts), replica-group size, and the
jax op_name metadata (maps to a model source line).  Sorted by ring-model
seconds — the top rows are the hillclimb targets.
"""

from __future__ import annotations

import gzip
import sys

from benchmarks.roofline import LINK_BW, RING_FACTOR
from repro.launch import hloparse


def site_report(text: str, top: int = 25):
    costs = hloparse.module_costs(text)
    rows = []
    for kind, b, g, m, name in costs.collective_sites:
        ring = RING_FACTOR.get(kind, lambda g: 1.0)(max(int(g), 1))
        rows.append({
            "kind": kind, "bytes": b, "mult": m, "group": g,
            "seconds": b * m * ring / LINK_BW,
            "op_name": name,
        })
    rows.sort(key=lambda r: -r["seconds"])
    return rows[:top] if top else rows


def main():
    path = sys.argv[1]
    text = gzip.open(path, "rt").read() if path.endswith(".gz") else open(path).read()
    rows = site_report(text, top=0)
    total = sum(r["seconds"] for r in rows)
    print(f"{len(rows)} collective sites, {total:.3f}s ring-model total; top 25:")
    for r in rows[:25]:
        print(f"  {r['seconds']:8.3f}s  {r['kind']:<18} {r['bytes']/1e6:9.1f}MB "
              f"x{r['mult']:<7.0f} g={r['group']:<4} {r['op_name'][-95:]}")


if __name__ == "__main__":
    main()
