"""Three-term roofline per (arch × shape × mesh) from the compiled dry-run.

Terms (TPU v5e constants; per-device program, so per-chip peak rates):

  compute_s    = exec_flops / 197e12            (bf16 MXU peak per chip)
  memory_s     = exec_bytes / 819e9             (HBM bandwidth per chip)
  collective_s = Σ_site ring_bytes(site) / 50e9 (ICI per link)

``exec_*`` are execution-weighted totals from ``repro.launch.hloparse``
(while bodies × known trip count — raw ``cost_analysis`` counts each body
once; see tests/test_hloparse.py).  Collective seconds model a
bidirectional-ring schedule per site:

  all-gather      (g-1)/g × result_bytes        (result = gathered array)
  reduce-scatter  (g-1)   × result_bytes        (result = one shard)
  all-reduce      2(g-1)/g × result_bytes       (RS + AG)
  all-to-all      (g-1)/g × result_bytes
  collective-permute      1 × result_bytes

MODEL_FLOPS (the "useful" flops): 6·N_active·D for training, 2·N_active·D
for prefill, 2·N_active·B per decode step — N_active excludes embedding
tables and counts each MoE expert at top_k/n_experts utilisation; an
attention term (12·L_attn·H·hd·S_eff train / 4·…·fwd-only) is added since
6ND ignores it and it is material at 32k.  The ratio
MODEL_FLOPS / (chips × exec_flops) exposes remat/redundancy waste.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
LINK_BW = 50e9        # bytes/s / ICI link

RING_FACTOR = {
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: float(g - 1),   # result = one shard
    "all-reduce": lambda g: 2 * (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


# ---------------------------------------------------------------------------
# analytic model flops
# ---------------------------------------------------------------------------

def _param_split(cfg):
    """(N_total, N_embed, N_expert_total) from the LM meta tree (no alloc)."""
    import jax
    from repro.models import LM
    from repro.models.module import is_meta

    model = LM(cfg)
    meta = model.meta()
    n_total = n_embed = n_expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        meta, is_leaf=is_meta
    )[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        n_total += n
        if keys and keys[0] == "embed":
            n_embed += n
        if (
            cfg.moe is not None
            and "ffn" in keys
            and leaf.shape
            and leaf.shape[-1 if "router" in keys else 0] == cfg.moe.n_experts
        ):
            if "router" not in keys:
                n_expert += n
    return n_total, n_embed, n_expert


def model_flops(cfg, shape: Dict, kind: str) -> float:
    """Global useful flops for one step of this cell."""
    B, S = shape["global_batch"], shape["seq_len"]
    n_total, n_embed, n_expert = _param_split(cfg)
    n_active = n_total - n_embed - n_expert
    if cfg.moe is not None and n_expert:
        n_active += n_expert * cfg.moe.top_k / cfg.moe.n_experts

    d_logits = 2 * cfg.d_model * cfg.vocab * cfg.n_codebooks

    # attention context term
    if cfg.n_heads:
        l_attn = cfg.n_layers
        if cfg.shared_attn_every:
            l_attn = cfg.n_layers // cfg.shared_attn_every
        hq = cfg.n_heads * cfg.head_dim
        s_eff = S / 2 if cfg.window is None else min(S / 2, cfg.window)
        attn_tok = 4 * l_attn * hq * s_eff   # fwd qk^T + att·v per token
        if cfg.xattn_every:
            attn_tok += 4 * (cfg.n_layers // cfg.xattn_every) * hq * cfg.n_img_tokens
    else:
        attn_tok = 0.0

    if kind == "train":
        tok = B * S
        return tok * (6 * n_active + 3 * d_logits + 3 * attn_tok)
    if kind == "prefill":
        tok = B * S
        return tok * (2 * n_active + 2 * attn_tok) + B * d_logits
    # decode: one token per sequence; attends to the whole cache (or window)
    s_ctx = S if cfg.window is None else min(S, cfg.window)
    if cfg.n_heads:
        l_attn = cfg.n_layers
        if cfg.shared_attn_every:
            l_attn = cfg.n_layers // cfg.shared_attn_every
        attn_dec = 4 * l_attn * cfg.n_heads * cfg.head_dim * s_ctx
        if cfg.xattn_every:
            attn_dec += (4 * (cfg.n_layers // cfg.xattn_every)
                         * cfg.n_heads * cfg.head_dim * cfg.n_img_tokens)
    else:
        attn_dec = 0.0
    return B * (2 * n_active + d_logits + attn_dec)


def analytic_memory_bytes(cfg, shape: Dict, kind: str, n_dev: int,
                          *, accum: int = 1) -> Dict[str, float]:
    """Per-device HBM traffic for one step on the TPU *target*.

    Why not HLO bytes alone: the CPU-backend HLO materializes chunked
    attention scores and unfused elementwise chains that the TPU build keeps
    in VMEM (flash_attention / ssd_scan Pallas kernels, fused adds) — its
    byte count is a fusion-pessimistic bound, reported separately.  This
    model counts what a tuned TPU program must actually move:

      params     3×P/tp train (fwd+bwd+remat re-read) | 1×P/tp inference
      grads      2×P/tp (write + reduce-scatter read)
      optimizer  30×N/n_dev f32 m/v/master read+write + bf16 param write
      acts       k_act × tokens_dev × d × a per layer
                 (k_act: fwd 12, +bwd 24, +remat 12 re-materialised reads)
      attention  flash: QKVO once + KV re-read per 128-row q block
      decode     whole resident KV (or SSM state) read per emitted token
      logits     chunked xent: hidden + vocab-shard weights + chunk logits
    """
    B, S = shape["global_batch"], shape["seq_len"]
    a = 2  # bf16
    tp = 16
    dp = n_dev // tp
    n_total, n_embed, n_expert = _param_split(cfg)
    p_bytes = n_total * a
    tokens_dev = B * S / dp
    d = cfg.d_model
    out = {}

    if kind in ("train", "prefill"):
        train = kind == "train"
        out["params"] = (3 if train else 1) * p_bytes / tp
        if train:
            out["grads"] = 2 * p_bytes / tp
            out["optimizer"] = 30 * n_total / n_dev
        k_act = 48 if train else 12
        out["acts"] = k_act * tokens_dev * d * a * cfg.n_layers
        if cfg.n_heads:
            l_attn = cfg.n_layers
            if cfg.shared_attn_every:
                l_attn = cfg.n_layers // cfg.shared_attn_every
            s_eff = S / 2 if cfg.window is None else min(S / 2, cfg.window)
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            qkvo = (2 * hq + 2 * hkv) * tokens_dev * hd * a / tp * l_attn
            kv_reread = (
                2 * (tokens_dev / 128) * s_eff * (hkv / min(hkv, tp)) * hd * a
                * l_attn
            )
            out["attention"] = (3 if train else 1) * (qkvo + kv_reread)
        vp = cfg.vocab * cfg.n_codebooks
        if train:
            out["logits"] = (
                4 * tokens_dev * vp * a / tp / 8   # chunk-resident logits
                + 2 * d * vp * a / tp              # vocab-shard weights
            )
        else:
            out["logits"] = 2 * d * vp * a / tp    # last-token only
    else:  # decode
        out["params"] = p_bytes / tp  # every weight read once per token
        if cfg.n_heads:
            l_attn = cfg.n_layers
            if cfg.shared_attn_every:
                l_attn = cfg.n_layers // cfg.shared_attn_every
            s_ctx = S if cfg.window is None else min(S, cfg.window)
            cache = (
                l_attn * 2 * cfg.n_kv_heads * cfg.head_dim * s_ctx * B * a
            ) / n_dev
            out["kv_cache"] = cache
        if cfg.ssm is not None:
            heads = d // cfg.ssm.head_dim
            state = cfg.n_layers * B * heads * cfg.ssm.head_dim * cfg.ssm.state * 4
            out["ssm_state"] = 2 * state / n_dev
        out["acts"] = 24 * (B / dp) * d * a * cfg.n_layers
        out["logits"] = d * cfg.vocab * cfg.n_codebooks * a / tp
    out["total"] = sum(out.values())
    return out


def model_flops_6nd(cfg, shape: Dict, kind: str) -> float:
    """The spec's bare convention: 6·N·D (train) / 2·N·D (inference)."""
    B, S = shape["global_batch"], shape["seq_len"]
    n_total, n_embed, n_expert = _param_split(cfg)
    n = n_total - n_embed - n_expert
    if cfg.moe is not None and n_expert:
        n += n_expert * cfg.moe.top_k / cfg.moe.n_experts
    tok = B * S if kind in ("train", "prefill") else B
    return (6 if kind == "train" else 2) * n * tok


# ---------------------------------------------------------------------------
# per-cell roofline
# ---------------------------------------------------------------------------

def collective_seconds(exec_sum: Dict) -> float:
    """Ring-model seconds over the per-link bandwidth."""
    sites = exec_sum.get("collective_sites") or []
    if sites:
        total = 0.0
        for s in sites:
            f = RING_FACTOR.get(s["kind"], lambda g: 1.0)(max(int(s["group"]), 1))
            total += s["bytes"] * s["mult"] * f
        return total / LINK_BW
    # fallback: raw sum (no group info)
    return sum(exec_sum.get("collective_bytes", {}).values()) / LINK_BW


def cell_roofline(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    from repro.configs import SHAPES, get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ex = rec["exec"]
    n_dev = rec["n_devices"]

    compute_s = ex["flops"] / PEAK_FLOPS
    memory_hlo_s = ex["bytes"] / HBM_BW
    mem = analytic_memory_bytes(cfg, shape, shape["kind"], n_dev)
    memory_s = mem["total"] / HBM_BW
    coll_s = collective_seconds(ex)
    coll_raw_s = sum(ex.get("collective_bytes", {}).values()) / LINK_BW

    mf = model_flops(cfg, shape, shape["kind"])
    mf6 = model_flops_6nd(cfg, shape, shape["kind"])
    useful = mf / (n_dev * ex["flops"]) if ex["flops"] else 0.0

    # bound/step estimate uses the analytic (TPU-fusion-aware) memory term;
    # the raw-HLO bytes term is reported alongside as the fusion-pessimistic
    # bound (CPU HLO materializes what the Pallas kernels keep in VMEM).
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bound = max(terms, key=terms.get)
    step_s = max(terms.values())
    mfu = (mf / (n_dev * PEAK_FLOPS)) / step_s if step_s else 0.0

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "n_devices": n_dev,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "memory_parts": mem,
        "collective_s": coll_s,
        "collective_raw_s": coll_raw_s,
        "bound": bound,
        "model_flops": mf,
        "model_flops_6nd": mf6,
        "useful_ratio": useful,
        "roofline_frac": mfu,
        "hbm_gib_per_dev": (
            rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
        ) / 2**30,
    }


_NOTES = {
    "compute": "compute-bound: raise useful-ratio (less remat recompute, fuse "
               "elementwise chains into the matmuls)",
    "memory": "HBM-bound: cut activation traffic (better remat policy, bf16 "
              "intermediates, larger fusion windows)",
    "collective": "ICI-bound: reshard to shrink per-layer gathers "
                  "(FSDP axis size, sequence-sharded activations, overlap "
                  "reduce-scatter with backward)",
}


def note_for(row: Dict) -> str:
    return _NOTES[row["bound"]]


def load_all(dirpath: str) -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        row = cell_roofline(rec)
        if row is not None:
            rows.append(row)
    return rows


def fmt_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "useful | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bound']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.dir)
    if args.markdown:
        print(fmt_table(rows, args.mesh))
        return rows
    print("bench,arch,shape,mesh,compute_s,memory_s,collective_s,bound,"
          "useful_ratio,roofline_frac")
    for r in rows:
        print(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['compute_s']:.5f},{r['memory_s']:.5f},{r['collective_s']:.5f},"
            f"{r['bound']},{r['useful_ratio']:.3f},{r['roofline_frac']:.4f}"
        )
    return rows


if __name__ == "__main__":
    main()
