"""Batched reachability benchmark: query-batch size × graph size × engine.

The workload family of the related papers (arXiv 1809.00896 reachability
queries, arXiv 2310.02380 wait-free snapshots) on top of this repo's graph:
build a graph with the ``traversal`` mix, compact it once into a consistent
CSR snapshot, then answer batches of ``reachable(u, v)`` pairs.

Engines:

  oracle   — pure-Python sequential BFS per query (the ground truth's cost)
  batched  — the jitted CSR frontier engine, whole query batch per dispatch

Two costs are reported separately: ``snap_ms`` (one-time CSR compaction per
graph version — amortized over every query until the next update batch) and
``us_per_query`` (marginal per-query cost at the given batch size).

CPU caveat (same as graph_throughput.py): the frontier expansion is one
gather + one scatter-max per level, and XLA lowers the scatter near-serially
on CPU, so absolute ``us_per_query`` compresses the batched engine's numbers;
the machine-independent content is the *scaling* in batch size (the whole
query batch rides one dispatch) and the one-dispatch snapshot cost.

Usage:  python benchmarks/graph_reachability.py [--quick]
Output: CSV rows on stdout (bench,engine,build,graph_size,batch,...).
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import WaitFreeGraph, traversal
from repro.core.workloads import initial_vertices, sample_batch, sample_query_pairs

GRAPH_SIZES = (256, 1024, 4096)
QUERY_BATCHES = (1, 16, 128, 1024)
ORACLE_MAX_BATCH = 128  # python BFS per query; cap its sweep and say so


def _build_graph(key_space: int, mode: str, seed: int = 0) -> WaitFreeGraph:
    """Pre-seeded vertices (the paper's initial graph) + traversal-mix
    traffic, so AddE lands on live endpoints and real path structure forms."""
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(v_capacity=4 * key_space, e_capacity=16 * key_space, mode=mode)
    g.apply(*initial_vertices(key_space))
    for _ in range(4):
        ops, us, vs = sample_batch(rng, key_space // 2, "traversal", key_space=key_space)
        g.apply(ops, us, vs)
    return g


def _bench_batched(g: WaitFreeGraph, pairs, timed: int):
    jax.block_until_ready(traversal.build_csr(g.state))  # warmup / compile
    t0 = time.perf_counter()
    csr = traversal.build_csr(g.state)
    jax.block_until_ready(csr)
    dt_snap = time.perf_counter() - t0
    us, vs = pairs
    r = traversal.reachable(csr, us, vs)  # warmup / compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(timed):
        r = traversal.reachable(csr, us, vs)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / timed, dt_snap, np.asarray(r)


def _bench_oracle(g: WaitFreeGraph, pairs, timed: int):
    from repro.core.oracle import SequentialGraph

    t0 = time.perf_counter()
    V, E = g.snapshot()
    o = SequentialGraph()
    o.vertices, o.edges = V, E
    dt_snap = time.perf_counter() - t0
    us, vs = pairs
    t0 = time.perf_counter()
    for _ in range(timed):
        out = [o.reachable(int(a), int(b)) for a, b in zip(us, vs)]
    dt = (time.perf_counter() - t0) / timed
    return dt, dt_snap, np.asarray(out)


def run(
    graph_sizes=GRAPH_SIZES,
    batches=QUERY_BATCHES,
    build_modes=("waitfree", "fpsp"),
    timed: int = 8,
    seed: int = 0,
) -> List[Dict]:
    rows = []
    for key_space in graph_sizes:
        for mode in build_modes:
            g = _build_graph(key_space, mode, seed)
            rng = np.random.default_rng(seed + 1)
            for n in batches:
                pairs = sample_query_pairs(rng, n, key_space)
                dt_b, snap_b, out_b = _bench_batched(g, pairs, timed)
                rows.append(dict(engine="batched", build=mode, graph_size=key_space,
                                 batch=n, snap_ms=1e3 * snap_b,
                                 us_per_query=1e6 * dt_b / n))
                if n > ORACLE_MAX_BATCH:
                    # stderr: stdout is the documented CSV contract
                    print(f"# dropped: oracle @ batch {n} (python BFS per query; "
                          f"capped at {ORACLE_MAX_BATCH})", file=sys.stderr)
                    continue
                dt_o, snap_o, out_o = _bench_oracle(g, pairs, max(1, timed // 4))
                assert out_b.tolist() == out_o.tolist(), "engines disagree"
                rows.append(dict(engine="oracle", build=mode, graph_size=key_space,
                                 batch=n, snap_ms=1e3 * snap_o,
                                 us_per_query=1e6 * dt_o / n))
    return rows


def main(quick: bool = False):
    rows = run(
        graph_sizes=(256, 1024) if quick else GRAPH_SIZES,
        batches=(16, 128) if quick else QUERY_BATCHES,
        build_modes=("waitfree",) if quick else ("waitfree", "fpsp"),
        timed=2 if quick else 8,
    )
    print("bench,engine,build,graph_size,batch,snap_ms,us_per_query")
    for r in rows:
        print(
            f"graph_reachability,{r['engine']},{r['build']},{r['graph_size']},"
            f"{r['batch']},{r['snap_ms']:.3f},{r['us_per_query']:.2f}"
        )
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv[1:])
