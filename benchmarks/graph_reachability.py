"""Batched reachability benchmark: query-batch size × graph size × engine,
plus frontier-kernel impls and rebuild-vs-delta snapshot maintenance.

The workload family of the related papers (arXiv 1809.00896 reachability
queries, arXiv 2310.02380 wait-free snapshots) on top of this repo's graph:
build a graph with the ``traversal`` mix, compact it once into a consistent
CSR snapshot, then answer batches of ``reachable(u, v)`` pairs.

Engine/impl columns:

  oracle / python        — pure-Python sequential BFS per query (ground truth)
  batched / reference    — jitted CSR frontier engine, pure-jnp expansion
  batched / kernel[...]  — same engine through the Pallas frontier kernel
                           (``kernel`` on TPU; ``kernel_interpret`` anywhere
                           with ``--kernels``, exercising the identical code
                           through the interpreter)

Maintenance rows (engine ``maintenance``) time the snapshot refresh after
each small update batch of an update-light query-heavy mix (the
``query_heavy`` regime): ``rebuild`` pays a full ``build_csr`` per batch,
``delta`` folds the batch in with ``traversal.apply_delta``.  ``snap_ms``
is the mean refresh cost; ``us_per_query`` amortizes it over a 256-query
window.  Delta below rebuild is the acceptance signal for incremental
maintenance.

Two costs are reported separately: ``snap_ms`` (snapshot compaction /
refresh per graph version — amortized over every query until the next
update batch) and ``us_per_query`` (marginal per-query cost at the given
batch size).

CPU caveat (same as graph_throughput.py): XLA lowers the frontier scatter
near-serially on CPU, so absolute ``us_per_query`` compresses the batched
engine's numbers; the machine-independent content is the *scaling* in batch
size (the whole query batch rides one dispatch), the one-dispatch snapshot
cost, and the rebuild-vs-delta ratio.

Usage:  python benchmarks/graph_reachability.py [--quick] [--kernels]
Output: CSV rows on stdout (bench,engine,impl,build,graph_size,batch,...).
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import WaitFreeGraph, traversal
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    sample_update_batch,
)

GRAPH_SIZES = (256, 1024, 4096)
QUERY_BATCHES = (1, 16, 128, 1024)
ORACLE_MAX_BATCH = 128  # python BFS per query; cap its sweep and say so
MAINT_QUERY_WINDOW = 256  # queries amortizing each maintenance refresh


def _build_graph(key_space: int, mode: str, seed: int = 0) -> WaitFreeGraph:
    """Pre-seeded vertices (the paper's initial graph) + traversal-mix
    traffic, so AddE lands on live endpoints and real path structure forms."""
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(v_capacity=4 * key_space, e_capacity=16 * key_space, mode=mode)
    g.apply(*initial_vertices(key_space))
    for _ in range(4):
        ops, us, vs = sample_batch(rng, key_space // 2, "traversal", key_space=key_space)
        g.apply(ops, us, vs)
    return g


def _bench_snap(g: WaitFreeGraph):
    """One-time CSR compaction cost — impl-independent, measured once per
    graph build and shared across the impl rows."""
    jax.block_until_ready(traversal.build_csr(g.state))  # warmup / compile
    t0 = time.perf_counter()
    csr = traversal.build_csr(g.state)
    jax.block_until_ready(csr)
    return time.perf_counter() - t0, csr


def _bench_batched(csr, pairs, timed: int, impl=None):
    us, vs = pairs
    r = traversal.reachable(csr, us, vs, impl=impl)  # warmup / compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(timed):
        r = traversal.reachable(csr, us, vs, impl=impl)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / timed, np.asarray(r)


def _bench_oracle(g: WaitFreeGraph, pairs, timed: int):
    from repro.core.oracle import SequentialGraph

    t0 = time.perf_counter()
    V, E = g.snapshot()
    o = SequentialGraph()
    o.vertices, o.edges = V, E
    dt_snap = time.perf_counter() - t0
    us, vs = pairs
    t0 = time.perf_counter()
    for _ in range(timed):
        out = [o.reachable(int(a), int(b)) for a, b in zip(us, vs)]
    dt = (time.perf_counter() - t0) / timed
    return dt, dt_snap, np.asarray(out)


def _bench_maintenance(
    key_space: int, mode: str, update_batch: int, n_batches: int, seed: int
) -> Dict[str, float]:
    """Mean snapshot-refresh ms per update batch, rebuild vs delta.

    One graph, one update stream; after every applied batch both refresh
    primitives are timed on the same post state — ``build_csr`` (what the
    ``rebuild`` policy pays) and ``apply_delta`` from the previous snapshot
    (what the ``delta`` policy pays; the result chains into the next round,
    and tests assert it is bit-identical to the rebuild)."""
    g = _build_graph(key_space, mode, seed)
    g.csr_maintenance = "rebuild"  # keep WaitFreeGraph out of the timings
    rng = np.random.default_rng(seed + 2)
    csr = traversal.build_csr(g.state)
    jax.block_until_ready(csr)
    # warmup: compile the delta probe/splice and the rebuild for this shape
    ops, us, vs = sample_update_batch(rng, update_batch, key_space)
    g.apply(ops, us, vs)
    jax.block_until_ready(traversal.build_csr(g.state))
    csr = traversal.apply_delta(csr, g.state, ops, us, vs)
    jax.block_until_ready(csr.src)
    t_rebuild = t_delta = 0.0
    for _ in range(n_batches):
        ops, us, vs = sample_update_batch(rng, update_batch, key_space)
        g.apply(ops, us, vs)
        t0 = time.perf_counter()
        full = traversal.build_csr(g.state)
        jax.block_until_ready(full)
        t_rebuild += time.perf_counter() - t0
        t0 = time.perf_counter()
        csr = traversal.apply_delta(csr, g.state, ops, us, vs)
        jax.block_until_ready(csr.src)
        t_delta += time.perf_counter() - t0
    return {
        "rebuild": 1e3 * t_rebuild / n_batches,
        "delta": 1e3 * t_delta / n_batches,
    }


def run(
    graph_sizes=GRAPH_SIZES,
    batches=QUERY_BATCHES,
    build_modes=("waitfree", "fpsp"),
    timed: int = 8,
    seed: int = 0,
    kernels: bool = False,
    maint_batches: int = 8,
) -> List[Dict]:
    impls = [("reference", "reference")]  # explicit: impl=None auto-picks the kernel on TPU
    if jax.default_backend() == "tpu":
        impls.append(("kernel", "kernel"))
    elif kernels:
        impls.append(("kernel_interpret", "kernel_interpret"))
    rows = []
    for key_space in graph_sizes:
        for mode in build_modes:
            g = _build_graph(key_space, mode, seed)
            rng = np.random.default_rng(seed + 1)
            snap_b, csr = _bench_snap(g)
            for n in batches:
                pairs = sample_query_pairs(rng, n, key_space)
                ref_out = None
                for impl_name, impl in impls:
                    dt_b, out_b = _bench_batched(csr, pairs, timed, impl)
                    rows.append(dict(engine="batched", impl=impl_name, build=mode,
                                     graph_size=key_space, batch=n,
                                     snap_ms=1e3 * snap_b,
                                     us_per_query=1e6 * dt_b / n))
                    if ref_out is None:
                        ref_out = out_b
                    else:
                        assert out_b.tolist() == ref_out.tolist(), "impls disagree"
                if n > ORACLE_MAX_BATCH:
                    # stderr: stdout is the documented CSV contract
                    print(f"# dropped: oracle @ batch {n} (python BFS per query; "
                          f"capped at {ORACLE_MAX_BATCH})", file=sys.stderr)
                    continue
                dt_o, snap_o, out_o = _bench_oracle(g, pairs, max(1, timed // 4))
                assert ref_out.tolist() == out_o.tolist(), "engines disagree"
                rows.append(dict(engine="oracle", impl="python", build=mode,
                                 graph_size=key_space, batch=n,
                                 snap_ms=1e3 * snap_o,
                                 us_per_query=1e6 * dt_o / n))
            # rebuild-vs-delta maintenance on the update-light mix
            update_batch = 16
            maint = _bench_maintenance(
                key_space, mode, update_batch, maint_batches, seed
            )
            for policy, snap_ms in maint.items():
                rows.append(dict(engine="maintenance", impl=policy, build=mode,
                                 graph_size=key_space, batch=update_batch,
                                 snap_ms=snap_ms,
                                 us_per_query=1e3 * snap_ms / MAINT_QUERY_WINDOW))
    return rows


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    kernels = "--kernels" in argv
    rows = run(
        # 512 floor: at 256 the whole edge table is small enough that a full
        # rebuild costs about as much as the delta's fixed overhead, and the
        # maintenance comparison drowns in scheduler noise on shared CI
        graph_sizes=(512, 1024) if quick else GRAPH_SIZES,
        batches=(16, 128) if quick else QUERY_BATCHES,
        build_modes=("waitfree",) if quick else ("waitfree", "fpsp"),
        timed=2 if quick else 8,
        kernels=kernels,
        maint_batches=8,
    )
    print("bench,engine,impl,build,graph_size,batch,snap_ms,us_per_query")
    for r in rows:
        print(
            f"graph_reachability,{r['engine']},{r['impl']},{r['build']},"
            f"{r['graph_size']},{r['batch']},{r['snap_ms']:.3f},"
            f"{r['us_per_query']:.2f}"
        )
    return rows


if __name__ == "__main__":
    main()
