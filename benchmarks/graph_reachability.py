"""Batched reachability benchmark: query-batch size × graph size × engine,
plus frontier-kernel impls and rebuild-vs-delta snapshot maintenance.

The workload family of the related papers (arXiv 1809.00896 reachability
queries, arXiv 2310.02380 wait-free snapshots) on top of this repo's graph:
build a graph with the ``traversal`` mix, compact it once into a consistent
CSR snapshot, then answer batches of ``reachable(u, v)`` pairs.

Engine/impl columns:

  oracle / python        — pure-Python sequential BFS per query (ground truth)
  batched / reference    — jitted CSR frontier engine, pure-jnp expansion
  batched / kernel[...]  — same engine through the Pallas frontier kernel
                           (``kernel`` on TPU; ``kernel_interpret`` anywhere
                           with ``--kernels``, exercising the identical code
                           through the interpreter)

Maintenance rows (engine ``maintenance``) time the two table-maintenance
hot paths:

* snapshot refresh after each small update batch of an update-light
  query-heavy mix: ``rebuild`` pays a full ``build_csr`` per batch,
  ``delta_host`` folds the batch with the numpy splice (O(valid edges)
  lexsort + host round-trip), ``delta_device`` with the fused device
  searchsorted merge (``repro.core.maintenance.delta_merge``).  The
  ``batch`` column sweeps the update-batch size: the device fold's cost
  should track the batch, not the live-edge count.
* growth rehash (``rehash_host`` vs ``rehash_device``, ``batch`` = 0):
  one capacity-doubling compaction of the current state, host claim
  rounds vs the ``kernels/compact`` placement pipeline.

``snap_ms`` is the mean refresh cost; ``us_per_query`` amortizes it over a
256-query window.  Delta below rebuild (and device at or below host) is
the acceptance signal.  The maintenance rows are also dumped to
``BENCH_maintenance.json`` so the perf trajectory is recorded per run.

Two costs are reported separately: ``snap_ms`` (snapshot compaction /
refresh per graph version — amortized over every query until the next
update batch) and ``us_per_query`` (marginal per-query cost at the given
batch size).

CPU caveat (same as graph_throughput.py): XLA lowers the frontier scatter
near-serially on CPU, so absolute ``us_per_query`` compresses the batched
engine's numbers; the machine-independent content is the *scaling* in batch
size (the whole query batch rides one dispatch), the one-dispatch snapshot
cost, and the rebuild-vs-delta ratio.

The ``n_shards`` column reports the hash-prefix shard count of the graph
the row was measured on (``repro.core.sharding``).  Query rows sweep it —
the batched engine answers against the *fused* cross-shard snapshot
(``fuse_partitioned``: canonical vertex directory + per-shard edge
validation), and all shard counts must agree bit-for-bit (asserted).
Maintenance rows come in both flavors: ``n_shards=1`` rows time the
per-shard primitives in isolation (rebuild / delta folds / one-table
rehash), and ``n_shards>1`` rows time the sharded pipeline end to end —
``rebuild_fused`` is the fused cross-shard refresh, ``rehash_host`` at
``n_shards>1`` doubles every shard against the shared gathered-endpoint
index (``rehash(..., endpoints=...)``).  ``peak_bytes`` is the largest
single shard's table footprint (bytes of its live arrays): the partitioned
design's O(N/S) memory claim as a measured column — it should fall ~1/S as
``n_shards`` rises on the same abstract graph.  See the README
"Benchmarks" section for how to read the CSV and ``BENCH_maintenance.json``.

Three obs-derived columns ride along (``docs/OBSERVABILITY.md``), computed
from each graph's *build* telemetry — the timed loops run with no registry
active: ``fastpath_frac`` (fraction of build ops that stayed on the FPSP
fast path; blank for non-FPSP builds with no conflict accounting),
``mean_probe_len`` (mean physical probe-chain length over both tables,
``repro.obs.probes``), ``claim_rounds_p99`` (p99 of claim rounds per
settle — the helping-bound witness).  The per-graph registries are dumped
to ``BENCH_obs.json`` (rendered by ``tools/obs_report.py``; CI uploads it
next to the CSV artifact), and ``tools/bench_regression.py`` gates on
``fastpath_frac`` drift.

Usage:  python benchmarks/graph_reachability.py [--quick] [--kernels]
Output: CSV rows on stdout
        (bench,engine,impl,build,graph_size,batch,n_shards,snap_ms,
        us_per_query,peak_bytes,fastpath_frac,mean_probe_len,
        claim_rounds_p99).
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import WaitFreeGraph, maintenance, sharding, traversal
from repro.obs import metrics as obsm
from repro.obs import probes as obsprobes
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    sample_update_batch,
)

GRAPH_SIZES = (256, 1024, 4096)
QUERY_BATCHES = (1, 16, 128, 1024)
ORACLE_MAX_BATCH = 128  # python BFS per query; cap its sweep and say so
MAINT_QUERY_WINDOW = 256  # queries amortizing each maintenance refresh


def _build_graph(
    key_space: int, mode: str, seed: int = 0, n_shards: int = 1,
    obs: bool = True,
) -> WaitFreeGraph:
    """Pre-seeded vertices (the paper's initial graph) + traversal-mix
    traffic, so AddE lands on live endpoints and real path structure forms.

    Each graph gets its own obs :class:`~repro.obs.metrics.Registry` so the
    build traffic's telemetry (fast-path fraction, claim rounds) is
    per-graph.  Only the *build* is instrumented — the timed query and
    maintenance loops below run with no registry active, so the numbers in
    the timing columns are obs-free (the overhead contract in
    ``docs/OBSERVABILITY.md``)."""
    rng = np.random.default_rng(seed)
    g = WaitFreeGraph(
        v_capacity=4 * key_space, e_capacity=16 * key_space, mode=mode,
        n_shards=n_shards, obs=obsm.Registry() if obs else False,
    )
    g.apply(*initial_vertices(key_space))
    for _ in range(4):
        ops, us, vs = sample_batch(rng, key_space // 2, "traversal", key_space=key_space)
        g.apply(ops, us, vs)
    return g


def _obs_columns(g: WaitFreeGraph) -> Dict:
    """The three obs-derived CSV columns for one built graph: build-traffic
    fast-path fraction, mean physical probe-chain length, and the p99 of
    claim rounds per settle.  ``None`` (blank CSV cell) where the registry
    saw no relevant traffic."""
    reg = g.obs
    if not reg.enabled:
        return dict(fastpath_frac=None, mean_probe_len=None,
                    claim_rounds_p99=None)
    g.probe_health()  # file probe.vertex / probe.edge hists into the registry
    return dict(
        fastpath_frac=obsm.fastpath_frac(reg),
        mean_probe_len=obsprobes.mean_probe_len(g),
        claim_rounds_p99=reg.percentile("engine.claim_rounds", 99),
    )


def _snap_csr(g: WaitFreeGraph):
    """The full snapshot-compaction pass: build_csr for a 1-shard graph,
    directory placement + partitioned fusion for a sharded one."""
    if g.n_shards == 1:
        return traversal.build_csr(g.state)
    return sharding.fuse_partitioned(g.shards)


def _graph_state_bytes(st) -> int:
    return int(sum(np.asarray(a).nbytes for a in st))


def _peak_shard_bytes(g: WaitFreeGraph) -> int:
    """Peak per-shard table footprint: bytes of the largest shard's live
    arrays.  The partitioned design's O(N/S) claim in one number — at a
    fixed abstract graph this column should fall ~1/S as n_shards rises
    (modulo the power-of-two capacity floor)."""
    states = g.shards if g.n_shards > 1 else [g.state]
    return max(_graph_state_bytes(st) for st in states)


def _bench_snap(g: WaitFreeGraph):
    """One-time CSR compaction cost — impl-independent, measured once per
    graph build and shared across the impl rows."""
    jax.block_until_ready(_snap_csr(g))  # warmup / compile
    t0 = time.perf_counter()
    csr = _snap_csr(g)
    jax.block_until_ready(csr)
    return time.perf_counter() - t0, csr


def _bench_batched(csr, pairs, timed: int, impl=None):
    us, vs = pairs
    r = traversal.reachable(csr, us, vs, impl=impl)  # warmup / compile
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(timed):
        r = traversal.reachable(csr, us, vs, impl=impl)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / timed, np.asarray(r)


def _bench_oracle(g: WaitFreeGraph, pairs, timed: int):
    from repro.core.oracle import SequentialGraph

    t0 = time.perf_counter()
    V, E = g.snapshot()
    o = SequentialGraph()
    o.vertices, o.edges = V, E
    dt_snap = time.perf_counter() - t0
    us, vs = pairs
    t0 = time.perf_counter()
    for _ in range(timed):
        out = [o.reachable(int(a), int(b)) for a, b in zip(us, vs)]
    dt = (time.perf_counter() - t0) / timed
    return dt, dt_snap, np.asarray(out)


def _bench_maintenance(
    key_space: int, mode: str, update_batch: int, n_batches: int, seed: int,
    kernels: bool = False,
) -> Dict[str, float]:
    """Mean snapshot-refresh ms per update batch: rebuild vs host delta vs
    device delta.

    One graph, one update stream; after every applied batch all three
    refresh primitives are timed on the same post state — ``build_csr``
    (what the ``rebuild`` policy pays) and ``apply_delta`` from the previous
    snapshot with the host splice and the device searchsorted merge (each
    chains its own snapshot into the next round; tests assert both are
    bit-identical to the rebuild)."""
    g = _build_graph(key_space, mode, seed)
    g.csr_maintenance = "rebuild"  # keep WaitFreeGraph out of the timings
    rng = np.random.default_rng(seed + 2)
    csr = traversal.build_csr(g.state)
    jax.block_until_ready(csr)
    # pass 1 — chain the folds once to (a) record each batch's (pre-CSR,
    # post-state) pair and (b) warm every per-bucket compile the stream
    # needs (touched-key buckets vary batch to batch; timing compiles would
    # charge the device merge for one-time costs the steady state never
    # pays again)
    steps = []
    for _ in range(n_batches):
        ops, us, vs = sample_update_batch(rng, update_batch, key_space)
        g.apply(ops, us, vs)
        steps.append((csr, g.state, ops, us, vs))
        csr = traversal.apply_delta(csr, g.state, ops, us, vs, impl="host")
    jax.block_until_ready(csr.src)
    impls = [("delta_host", "host"), ("delta_device", "device")]
    if kernels and jax.default_backend() != "tpu":
        impls.append(("delta_device_interpret", "device_interpret"))
    for pre, state, ops, us, vs in steps:
        jax.block_until_ready(traversal.build_csr(state))
        for _, impl in impls[1:]:
            jax.block_until_ready(
                traversal.apply_delta(pre, state, ops, us, vs, impl=impl).src
            )
    # pass 2 — steady-state timing over the identical work
    timers = {"rebuild": 0.0, **{name: 0.0 for name, _ in impls}}
    for pre, state, ops, us, vs in steps:
        t0 = time.perf_counter()
        jax.block_until_ready(traversal.build_csr(state))
        timers["rebuild"] += time.perf_counter() - t0
        for name, impl in impls:
            t0 = time.perf_counter()
            out = traversal.apply_delta(pre, state, ops, us, vs, impl=impl)
            jax.block_until_ready(out.src)
            timers[name] += time.perf_counter() - t0
    return {k: 1e3 * t / n_batches for k, t in timers.items()}


def _bench_rehash(g: WaitFreeGraph, timed: int, kernels: bool = False) -> Dict[str, float]:
    """Mean growth-rehash ms (one capacity doubling of the current state),
    host claim rounds vs the device compaction pipeline (plus the Pallas
    interpreter row with ``--kernels`` off-TPU, for the parity artifact)."""
    state = g.state
    nv, ne = 2 * state.v_capacity, 2 * state.e_capacity
    impls = ["host", "device"]
    if kernels and jax.default_backend() != "tpu":
        impls.append("device_interpret")
    out = {}
    for impl in impls:
        s, _, ok = maintenance.rehash(state, nv, ne, impl=impl)  # warmup/compile
        assert ok
        jax.block_until_ready(s.v_key)
        t0 = time.perf_counter()
        for _ in range(timed):
            s, _, ok = maintenance.rehash(state, nv, ne, impl=impl)
            jax.block_until_ready(s.v_key)
        out[f"rehash_{impl}"] = 1e3 * (time.perf_counter() - t0) / timed
    return out


def _bench_sharded_maintenance(
    key_space: int, mode: str, update_batch: int, n_batches: int, seed: int,
    n_shards: int,
):
    """The sharded counterparts of the maintenance rows: snapshot refresh is
    a fused per-shard rebuild (``fuse_partitioned`` — directory placement +
    per-shard edge validation), growth rehash doubles every shard against
    the shared gathered-endpoint index (``rehash(..., endpoints=...)``).
    Reported ms are totals across all shards, so they compare directly to
    the 1-shard rows on the same abstract graph."""
    g = _build_graph(key_space, mode, seed, n_shards)
    rng = np.random.default_rng(seed + 2)
    jax.block_until_ready(sharding.fuse_partitioned(g.shards).src)  # warmup
    t_refresh = 0.0
    for _ in range(n_batches):
        ops, us, vs = sample_update_batch(rng, update_batch, key_space)
        g.apply(ops, us, vs)
        t0 = time.perf_counter()
        csr = sharding.fuse_partitioned(g.shards)
        jax.block_until_ready(csr.src)
        t_refresh += time.perf_counter() - t0

    endpoints = sharding.gather_live_vertices(g.shards)

    def grow_all():
        for st in g.shards:
            s, _, ok = maintenance.rehash(
                st, 2 * st.v_capacity, 2 * st.e_capacity,
                impl="host", endpoints=endpoints,
            )
            assert ok
            jax.block_until_ready(s.v_key)

    grow_all()  # warmup / compile
    t0 = time.perf_counter()
    grow_all()
    t_rehash = time.perf_counter() - t0
    return (
        {
            "rebuild_fused": 1e3 * t_refresh / n_batches,
            "rehash_host": 1e3 * t_rehash,
        },
        g,
    )


def run(
    graph_sizes=GRAPH_SIZES,
    batches=QUERY_BATCHES,
    build_modes=("waitfree", "fpsp"),
    timed: int = 8,
    seed: int = 0,
    kernels: bool = False,
    maint_batches: int = 8,
    update_batches=(8, 32, 128),
    shard_counts=(1, 4),
    obs_out: Dict = None,
) -> List[Dict]:
    impls = [("reference", "reference")]  # explicit: impl=None auto-picks the kernel on TPU
    if jax.default_backend() == "tpu":
        impls.append(("kernel", "kernel"))
    elif kernels:
        impls.append(("kernel_interpret", "kernel_interpret"))
    rows = []
    for key_space in graph_sizes:
        for mode in build_modes:
            # query rows sweep the shard count: same seed -> same op stream
            # and same query pairs, so the fused-snapshot answers must agree
            # bit-for-bit with the 1-shard graph's (asserted below)
            shard_ref: Dict[int, List] = {}
            for n_shards in shard_counts:
                g = _build_graph(key_space, mode, seed, n_shards)
                ocols = _obs_columns(g)
                if obs_out is not None:
                    obs_out[f"{mode}/ks{key_space}/shards{n_shards}"] = (
                        g.obs.dump()
                    )
                rng = np.random.default_rng(seed + 1)
                pb = _peak_shard_bytes(g)
                snap_b, csr = _bench_snap(g)
                for n in batches:
                    pairs = sample_query_pairs(rng, n, key_space)
                    ref_out = None
                    for impl_name, impl in impls:
                        dt_b, out_b = _bench_batched(csr, pairs, timed, impl)
                        rows.append(dict(engine="batched", impl=impl_name, build=mode,
                                         graph_size=key_space, batch=n,
                                         n_shards=n_shards,
                                         snap_ms=1e3 * snap_b,
                                         us_per_query=1e6 * dt_b / n,
                                         peak_bytes=pb, **ocols))
                        if ref_out is None:
                            ref_out = out_b
                        else:
                            assert out_b.tolist() == ref_out.tolist(), "impls disagree"
                    cross = shard_ref.setdefault(n, ref_out.tolist())
                    assert ref_out.tolist() == cross, "shard counts disagree"
                    if n_shards != shard_counts[0]:
                        continue  # oracle ground truth once per (mode, batch)
                    if n > ORACLE_MAX_BATCH:
                        # stderr: stdout is the documented CSV contract
                        print(f"# dropped: oracle @ batch {n} (python BFS per "
                              f"query; capped at {ORACLE_MAX_BATCH})",
                              file=sys.stderr)
                        continue
                    dt_o, snap_o, out_o = _bench_oracle(g, pairs, max(1, timed // 4))
                    assert ref_out.tolist() == out_o.tolist(), "engines disagree"
                    rows.append(dict(engine="oracle", impl="python", build=mode,
                                     graph_size=key_space, batch=n,
                                     n_shards=n_shards,
                                     snap_ms=1e3 * snap_o,
                                     us_per_query=1e6 * dt_o / n,
                                     peak_bytes=pb, **ocols))
            # rebuild-vs-delta maintenance on the update-light mix; the
            # update-batch sweep exposes what each refresh scales with
            # (the device merge should track batch size, the host splice
            # and the rebuild the live-edge count / capacity).  n_shards=1:
            # the refresh primitives are per-shard by construction, so the
            # single-shard number is the per-shard cost.
            g = _build_graph(key_space, mode, seed)
            ocols1 = _obs_columns(g)
            if obs_out is not None:
                obs_out[f"{mode}/ks{key_space}/maint"] = g.obs.dump()
            pb1 = _peak_shard_bytes(g)
            for update_batch in update_batches:
                maint = _bench_maintenance(
                    key_space, mode, update_batch, maint_batches, seed,
                    kernels=kernels,
                )
                for policy, snap_ms in maint.items():
                    rows.append(dict(engine="maintenance", impl=policy, build=mode,
                                     graph_size=key_space, batch=update_batch,
                                     n_shards=1,
                                     snap_ms=snap_ms,
                                     us_per_query=1e3 * snap_ms / MAINT_QUERY_WINDOW,
                                     peak_bytes=pb1, **ocols1))
            # growth rehash: host claim rounds vs device compaction pipeline
            for policy, snap_ms in _bench_rehash(
                g, max(2, timed // 4), kernels=kernels
            ).items():
                rows.append(dict(engine="maintenance", impl=policy, build=mode,
                                 graph_size=key_space, batch=0,
                                 n_shards=1,
                                 snap_ms=snap_ms,
                                 us_per_query=1e3 * snap_ms / MAINT_QUERY_WINDOW,
                                 peak_bytes=pb1, **ocols1))
            # the sharded counterparts: fused refresh + endpoint-indexed
            # per-shard rehash, peak_bytes showing the O(N/S) footprint
            s_last = shard_counts[-1]
            if s_last > 1:
                maint_s, gs = _bench_sharded_maintenance(
                    key_space, mode, update_batches[0], maint_batches, seed,
                    s_last,
                )
                pbs = _peak_shard_bytes(gs)
                ocols_s = _obs_columns(gs)
                if obs_out is not None:
                    obs_out[f"{mode}/ks{key_space}/maint_shards{s_last}"] = (
                        gs.obs.dump()
                    )
                for policy, snap_ms in maint_s.items():
                    rows.append(dict(engine="maintenance", impl=policy,
                                     build=mode, graph_size=key_space,
                                     batch=0 if policy.startswith("rehash")
                                     else update_batches[0],
                                     n_shards=s_last,
                                     snap_ms=snap_ms,
                                     us_per_query=1e3 * snap_ms
                                     / MAINT_QUERY_WINDOW,
                                     peak_bytes=pbs, **ocols_s))
    return rows


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    quick = "--quick" in argv
    kernels = "--kernels" in argv
    obs_dumps: Dict[str, Dict] = {}
    rows = run(
        # 512 floor: at 256 the whole edge table is small enough that a full
        # rebuild costs about as much as the delta's fixed overhead, and the
        # maintenance comparison drowns in scheduler noise on shared CI
        graph_sizes=(512, 1024) if quick else GRAPH_SIZES,
        batches=(16, 128) if quick else QUERY_BATCHES,
        # quick keeps one mode; fpsp so the fastpath_frac column (and the
        # BENCH_obs.json artifact CI uploads) carries the FPSP telemetry
        build_modes=("fpsp",) if quick else ("waitfree", "fpsp"),
        timed=2 if quick else 8,
        kernels=kernels,
        maint_batches=4 if quick else 8,
        update_batches=(8, 64) if quick else (8, 32, 128),
        shard_counts=(1, 2) if quick else (1, 4),
        obs_out=obs_dumps,
    )

    def _cell(v, fmt):
        return "" if v is None else format(v, fmt)

    print("bench,engine,impl,build,graph_size,batch,n_shards,snap_ms,"
          "us_per_query,peak_bytes,fastpath_frac,mean_probe_len,"
          "claim_rounds_p99")
    for r in rows:
        print(
            f"graph_reachability,{r['engine']},{r['impl']},{r['build']},"
            f"{r['graph_size']},{r['batch']},{r['n_shards']},{r['snap_ms']:.3f},"
            f"{r['us_per_query']:.2f},{r['peak_bytes']},"
            f"{_cell(r['fastpath_frac'], '.4f')},"
            f"{_cell(r['mean_probe_len'], '.3f')},"
            f"{_cell(r['claim_rounds_p99'], '.1f')}"
        )
    # the maintenance trajectory, machine-readable (CI uploads it next to
    # the CSV artifact)
    maint_rows = [r for r in rows if r["engine"] == "maintenance"]
    with open("BENCH_maintenance.json", "w") as f:
        json.dump(
            {
                "bench": "graph_reachability/maintenance",
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "quick": quick,
                "rows": maint_rows,
            },
            f,
            indent=2,
        )
    print(f"# maintenance rows -> BENCH_maintenance.json ({len(maint_rows)} rows)",
          file=sys.stderr)
    # per-graph build telemetry (counters, claim-round + probe histograms,
    # phase spans), machine-readable — ``tools/obs_report.py`` renders it
    with open("BENCH_obs.json", "w") as f:
        json.dump(
            {
                "bench": "graph_reachability/obs",
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "quick": quick,
                "graphs": obs_dumps,
            },
            f,
            indent=2,
        )
    print(f"# build telemetry -> BENCH_obs.json ({len(obs_dumps)} graphs)",
          file=sys.stderr)
    return rows


if __name__ == "__main__":
    main()
