"""Hillclimb runner: re-lower one cell with run/config overrides and diff
the roofline terms against the baseline JSON.

  PYTHONPATH=src:. python -m benchmarks.hillclimb --arch qwen2-7b \
      --shape train_4k --tag attnseq --set attn_seq_shard=true

Writes results/hillclimb/<arch>__<shape>__<tag>.json (same schema as the
dry-run) and prints a before/after table of the three terms — the artifact
EXPERIMENTS.md §Perf records per iteration.
"""

# device-count override must precede any jax import
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json      # noqa: E402


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="run-dict override key=val (repeatable)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--baseline-dir", default="results/dryrun")
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell
    from benchmarks.roofline import cell_roofline

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    step_overrides = {"run_overrides": overrides} if overrides else {}
    if args.accum is not None:
        step_overrides["accum"] = args.accum

    os.makedirs(args.out, exist_ok=True)
    hlo_dir = os.path.join(args.out, "hlo")
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   hlo_dir=hlo_dir, step_overrides=step_overrides)
    sfx = "mp" if args.multi_pod else "sp"
    # run_cell writes HLO under arch__shape__sfx; rename to include the tag
    src = os.path.join(hlo_dir, f"{args.arch}__{args.shape}__{sfx}.hlo.gz")
    dst = os.path.join(hlo_dir, f"{args.arch}__{args.shape}__{sfx}__{args.tag}.hlo.gz")
    if os.path.exists(src):
        os.replace(src, dst)
    out_path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{sfx}__{args.tag}.json"
    )
    rec["overrides"] = overrides
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)

    new = cell_roofline(rec)
    base_path = os.path.join(
        args.baseline_dir, f"{args.arch}__{args.shape}__{sfx}.json"
    )
    with open(base_path) as f:
        base = cell_roofline(json.load(f))

    print(f"\n=== {args.arch} × {args.shape} × {sfx} | variant '{args.tag}' "
          f"{overrides} ===")
    print(f"{'term':<14}{'baseline':>12}{'variant':>12}{'delta':>9}")
    for key in ("compute_s", "memory_s", "collective_s", "roofline_frac"):
        b, n = base[key], new[key]
        d = (n - b) / b * 100 if b else float("nan")
        print(f"{key:<14}{b:>12.4f}{n:>12.4f}{d:>+8.1f}%")
    print(f"bound: {base['bound']} -> {new['bound']}")


if __name__ == "__main__":
    main()
