"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--full]``.

One section per paper table/figure plus the framework benches:

  graph_throughput — paper Fig. 4 (3 mixes × 5 engines × lane sweep)
  serving_paged_kv — wait-free paged KV vs contiguous (beyond-paper)
  lm_step          — per-arch smoke train/decode step timings
  roofline         — 3-term roofline per dry-run cell (reads results/dryrun)

Everything prints CSV rows ``bench,<fields...>`` so the output diffs cleanly
across runs.
"""

from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full lane sweep + all archs (default: quick)")
    ap.add_argument("--skip", default="", help="comma list of sections")
    args = ap.parse_args()
    quick = not args.full
    skip = set(filter(None, args.skip.split(",")))

    from benchmarks import graph_throughput, lm_step_bench, serving_bench

    if "graph" not in skip:
        print("# === graph_throughput (paper Fig. 4) ===")
        # default: 3-point lane sweep (1/32/512) — the full 5-point sweep
        # (--full) adds ~40 min of engine compiles on this 1-core box
        graph_throughput.main(quick=quick)
    if "serving" not in skip:
        print("# === serving_paged_kv ===")
        serving_bench.main(quick=quick)
    if "lm" not in skip:
        print("# === lm_step ===")
        lm_step_bench.main(quick=quick)
    if "roofline" not in skip:
        d = ("results/dryrun_opt" if os.path.isdir("results/dryrun_opt")
             else "results/dryrun")
        print(f"# === roofline (from {d}) ===")
        if os.path.isdir(d):
            from benchmarks import roofline
            sys.argv = ["roofline", "--dir", d]
            roofline.main()
        else:
            print("# results/dryrun missing — run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
