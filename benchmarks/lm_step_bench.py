"""Per-arch smoke-scale step timings on CPU.

Not a TPU performance claim (CPU backend; the roofline tables are the perf
deliverable) — this is the harness that proves every assigned architecture's
train and decode step *runs*, and tracks relative regressions across code
changes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.launch.steps import build_decode_step, build_train_step
from repro.models import LM
from repro.optim import adamw_init


def bench_arch(name: str, steps: int = 3):
    with jax.make_mesh((1, 1), ("data", "model")):
        return _bench_arch(name, steps)


def _bench_arch(name: str, steps: int = 3):
    cfg = get_smoke_config(name)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    B, S = 4, 32

    # train
    train_step, _, _ = build_train_step(cfg, multi_pod=False, accum=1)
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    tok_shape = (B, S) if cfg.n_codebooks == 1 else (B, S, cfg.n_codebooks)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, tok_shape), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, tok_shape), jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.xattn_every:
        batch["memory"] = jnp.zeros((B, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype)
    jitted = jax.jit(train_step)
    out = jitted(params, opt, batch)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(steps):
        out = jitted(params, opt, batch)
    jax.block_until_ready(out)
    train_us = 1e6 * (time.perf_counter() - t0) / steps

    # decode
    decode_step, _, _ = build_decode_step(cfg, multi_pod=False)
    cache = model.decode_init(B, S, params=params)
    tok1 = (B, 1) if cfg.n_codebooks == 1 else (B, 1, cfg.n_codebooks)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, tok1), jnp.int32)
    kwargs = {}
    if cfg.xattn_every:
        kwargs["memory"] = batch["memory"]
    jd = jax.jit(decode_step)
    out = jd(params, tok, cache, **kwargs)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(steps):
        out = jd(params, tok, cache, **kwargs)
    jax.block_until_ready(out)
    decode_us = 1e6 * (time.perf_counter() - t0) / steps
    return train_us, decode_us


def main(quick: bool = False):
    archs = ARCH_NAMES[:3] if quick else ARCH_NAMES
    print("bench,arch,train_us,decode_us")
    rows = []
    for name in archs:
        tr, de = bench_arch(name)
        print(f"lm_step,{name},{tr:.0f},{de:.0f}")
        rows.append((name, tr, de))
    return rows


if __name__ == "__main__":
    main()
