"""Fast-path-slow-path (paper §3.4, after Kogan–Petrank / Timnat et al.).

The paper's fast path runs the Harris lock-free op and falls back to the
helped (wait-free) path after MAX_FAIL CAS failures; the observation is that
contention is rare, so the slow machinery is almost never paid.

Dataflow analogue: the cost the wait-free engine pays per batch is the
(key, phase) sorts and scans.  An op needs none of that if nothing else in
the batch can interfere with it:

  * vertex op on key u — no other op in the batch touches u (as a vertex op
    or as an edge endpoint);
  * edge op on (u, v) — (u, v) is unique among edge ops AND neither endpoint
    has any vertex op in the batch (Fig. 3: a concurrent vertex op is exactly
    what moves an edge op's linearization point).

Such ops are resolved directly from the table (one gather + one scatter,
sort-free): the fast path.  The conflicted remainder — typically a tiny
fraction, mirroring the paper's "very less number of failures" — is resolved
by the full wait-free engine with the fast ops masked to NOPs.  Both paths
are bounded, so the hybrid is still wait-free, and `lax.cond` skips the slow
pass entirely when a batch is conflict-free.

Under hash-prefix sharding (:mod:`repro.core.sharding`) each shard's
sub-batch holds only its owned ops, and endpoint liveness arrives from the
cross-shard stabbing wave instead of the local table; the partitioned FPSP
entry point is :func:`settle_edges_fpsp`, whose conflict mask reduces to
duplicate ``(u, v)`` detection because the stab answers already fold in
every concurrent vertex op.  Paper-to-code map: ``docs/ARCHITECTURE.md``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import engine, types
from .locate import claim_edge_slots, claim_vertex_slots, locate_edges, locate_vertices
from .types import (
    ABSENT_INC,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_NOP,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    ApplyResult,
    GraphState,
    OpBatch,
)

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _dup_mask(keys: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Exact: True where ``keys[i]`` appears more than once among active
    lanes.  One stable sort + neighbour compare; inactive lanes carry the
    INT32_MAX sentinel and are masked out."""
    k = jnp.where(active, keys, _INT32_MAX)
    order = jnp.argsort(k)
    ks, act_s = k[order], active[order]
    eq = ks[1:] == ks[:-1]
    false1 = jnp.zeros((1,), bool)
    dup_s = (jnp.concatenate([false1, eq]) | jnp.concatenate([eq, false1])) & act_s
    return jnp.zeros_like(dup_s).at[order].set(dup_s)


def _edge_dup_mask(u: jnp.ndarray, v: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Exact duplicate-(u,v) detection via a lexicographic (two-pass stable)
    sort + neighbour compare."""
    uu = jnp.where(active, u, _INT32_MAX)
    vv = jnp.where(active, v, _INT32_MAX)
    p1 = jnp.argsort(vv)
    perm = p1[jnp.argsort(uu[p1])]
    us, vs = uu[perm], vv[perm]
    eq = (us[1:] == us[:-1]) & (vs[1:] == vs[:-1])
    false1 = jnp.zeros((1,), bool)
    dup_s = (jnp.concatenate([false1, eq]) | jnp.concatenate([eq, false1])) & active[perm]
    return jnp.zeros_like(dup_s).at[perm].set(dup_s)


def _membership_count(query: jnp.ndarray, ref: jnp.ndarray, ref_active: jnp.ndarray):
    """Exact count of each ``query`` key among active ``ref`` keys
    (searchsorted over the sorted reference; sentinels sort to the top and
    never match real keys)."""
    r = jnp.sort(jnp.where(ref_active, ref, _INT32_MAX))
    lo = jnp.searchsorted(r, query, side="left")
    hi = jnp.searchsorted(r, query, side="right")
    return (hi - lo).astype(jnp.int32)


def _conflict_mask(batch: OpBatch):
    """True where an op may interact with another op in the same batch.

    Exact (sort/searchsorted based, no hashing): a false positive here only
    costs throughput, but an earlier count-min-hash version demoted ~25% of a
    conflict-free batch to the slow path from birthday collisions alone —
    the paper's whole FPSP premise is that the slow path is rare, so the
    detector must not manufacture conflicts."""
    op, u, v = batch.op, batch.u, batch.v

    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    is_eop = (op == OP_ADD_EDGE) | (op == OP_REMOVE_EDGE) | (op == OP_CONTAINS_EDGE)

    # vertex op conflicts: another vertex op on u, or any edge op touching u
    e_endpoints = jnp.concatenate([u, v])
    e_ep_active = jnp.concatenate([is_eop, is_eop])
    v_conf = is_vop & (
        _dup_mask(u, is_vop)
        | (_membership_count(u, e_endpoints, e_ep_active) > 0)
    )
    # edge op conflicts: duplicate (u,v), or any vertex op on either endpoint
    # (paper Fig. 3: a concurrent vertex op moves the edge op's linearization
    # point, so those must go through the phase-ordered slow path)
    edge_dup = is_eop & _edge_dup_mask(u, v, is_eop)
    e_conf = edge_dup | (
        is_eop
        & ((_membership_count(u, u, is_vop) > 0) | (_membership_count(v, u, is_vop) > 0))
    )
    # the per-reason masks (v_conf / e_conf / edge_dup) feed the stats
    # vector: the obs layer splits the slow-path trigger count by cause
    return (v_conf | e_conf) & (is_vop | is_eop), is_vop, is_eop, v_conf, e_conf, edge_dup


def _fast_apply(state: GraphState, batch: OpBatch, fast: jnp.ndarray):
    """Resolve conflict-free ops straight from the table state."""
    op, u, v = batch.op, batch.u, batch.v
    n = op.shape[0]

    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    is_eop = ~is_vop & (op != OP_NOP)
    fv = fast & is_vop
    fe = fast & is_eop

    # ---- vertices ----
    vloc = locate_vertices(state.v_key, jnp.where(fv, u, _INT32_MAX), fv)
    vsafe = jnp.where(vloc.found, vloc.slot, 0)
    vlive = jnp.where(vloc.found, state.v_live[vsafe], False)
    vinc = jnp.where(vloc.found, state.v_inc[vsafe], ABSENT_INC)

    addv = fv & (op == OP_ADD_VERTEX)
    remv = fv & (op == OP_REMOVE_VERTEX)
    conv = fv & (op == OP_CONTAINS_VERTEX)
    v_success = (addv & ~vlive) | ((remv | conv) & vlive)

    cap = state.v_key.shape[0]
    # revive/insert on successful add; mark dead on successful remove
    wr = (addv | remv) & v_success & vloc.found
    wslot = jnp.where(wr, vloc.slot, cap)
    v_live_new = state.v_live.at[wslot].set(addv & v_success, mode="drop")
    v_inc_new = state.v_inc.at[wslot].set(
        jnp.where(addv, vinc + 1, vinc), mode="drop"
    )
    # brand-new keys (not found): insert via scatter-claim (keys unique by
    # construction of the fast set)
    need_ins = addv & v_success & ~vloc.found
    v_key_new, new_slots, v_over, v_rounds = claim_vertex_slots(
        state.v_key, jnp.where(need_ins, u, _INT32_MAX), need_ins
    )
    islot = jnp.where(need_ins & (new_slots >= 0), new_slots, cap)
    v_live_new = v_live_new.at[islot].set(True, mode="drop")
    v_inc_new = v_inc_new.at[islot].set(0, mode="drop")

    state = state._replace(v_key=v_key_new, v_live=v_live_new, v_inc=v_inc_new)

    # ---- edges ----
    # endpoints: table state is authoritative (no concurrent vertex ops on
    # them — that is the fast-path precondition)
    uloc = locate_vertices(state.v_key, jnp.where(fe, u, _INT32_MAX), fe)
    vloc2 = locate_vertices(state.v_key, jnp.where(fe, v, _INT32_MAX), fe)
    usafe = jnp.where(uloc.found, uloc.slot, 0)
    vsafe2 = jnp.where(vloc2.found, vloc2.slot, 0)
    u_live = jnp.where(uloc.found, state.v_live[usafe], False)
    v_live = jnp.where(vloc2.found, state.v_live[vsafe2], False)
    u_inc = jnp.where(uloc.found, state.v_inc[usafe], ABSENT_INC)
    v_inc = jnp.where(vloc2.found, state.v_inc[vsafe2], ABSENT_INC)
    eligible = u_live & v_live & fe

    eloc = locate_edges(
        state.e_key_u, state.e_key_v,
        jnp.where(fe, u, _INT32_MAX), jnp.where(fe, v, _INT32_MAX), fe,
    )
    esafe = jnp.where(eloc.found, eloc.slot, 0)
    e_valid = (
        eloc.found
        & state.e_live[esafe]
        & (state.e_inc_u[esafe] == u_inc)
        & (state.e_inc_v[esafe] == v_inc)
        & eligible
    )

    adde = fe & (op == OP_ADD_EDGE)
    reme = fe & (op == OP_REMOVE_EDGE)
    cone = fe & (op == OP_CONTAINS_EDGE)
    e_success = (adde & eligible & ~e_valid) | ((reme | cone) & e_valid)

    ecap = state.e_key_u.shape[0]
    ewr = ((adde | reme) & e_success & eloc.found)
    ewslot = jnp.where(ewr, eloc.slot, ecap)
    e_live_new = state.e_live.at[ewslot].set(adde & e_success, mode="drop")
    e_bu_new = state.e_inc_u.at[ewslot].set(u_inc, mode="drop")
    e_bv_new = state.e_inc_v.at[ewslot].set(v_inc, mode="drop")

    e_need_ins = adde & e_success & ~eloc.found
    e_ku_new, e_kv_new, e_new_slots, e_over, e_rounds = claim_edge_slots(
        state.e_key_u, state.e_key_v,
        jnp.where(e_need_ins, u, _INT32_MAX), jnp.where(e_need_ins, v, _INT32_MAX),
        e_need_ins,
    )
    eislot = jnp.where(e_need_ins & (e_new_slots >= 0), e_new_slots, ecap)
    e_live_new = e_live_new.at[eislot].set(True, mode="drop")
    e_bu_new = e_bu_new.at[eislot].set(u_inc, mode="drop")
    e_bv_new = e_bv_new.at[eislot].set(v_inc, mode="drop")

    state = state._replace(
        e_key_u=e_ku_new, e_key_v=e_kv_new,
        e_live=e_live_new, e_inc_u=e_bu_new, e_inc_v=e_bv_new,
    )

    success = jnp.where(fv, v_success, jnp.where(fe, e_success, False))
    overflow = vloc.overflow | uloc.overflow | vloc2.overflow | eloc.overflow | v_over | e_over
    n_ins = (
        jnp.sum(need_ins & (new_slots >= 0)) + jnp.sum(e_need_ins & (e_new_slots >= 0))
    ).astype(jnp.int32)
    return state, success, overflow, n_ins, v_rounds + e_rounds


def _fast_apply_edges(state: GraphState, batch: OpBatch, fe, endpoint):
    """The edge half of :func:`_fast_apply`, fed externally settled endpoint
    (live, inc)-at-phase answers instead of table reads.

    Under vertex partitioning (:mod:`repro.core.sharding`) a shard cannot
    read non-owned endpoints from its local table — the stabbing wave's
    answers replace that read, and they are exact *at each op's phase*, so
    the fast-path precondition shrinks to "``(u, v)`` unique among this
    shard's edge ops" (concurrent vertex ops no longer disqualify a lane:
    their effect is already folded into the answers)."""
    op, u, v = batch.op, batch.u, batch.v
    u_live, u_inc, v_live, v_inc = endpoint
    eligible = u_live & v_live & fe

    eloc = locate_edges(
        state.e_key_u, state.e_key_v,
        jnp.where(fe, u, _INT32_MAX), jnp.where(fe, v, _INT32_MAX), fe,
    )
    esafe = jnp.where(eloc.found, eloc.slot, 0)
    e_valid = (
        eloc.found
        & state.e_live[esafe]
        & (state.e_inc_u[esafe] == u_inc)
        & (state.e_inc_v[esafe] == v_inc)
        & eligible
    )

    adde = fe & (op == OP_ADD_EDGE)
    reme = fe & (op == OP_REMOVE_EDGE)
    cone = fe & (op == OP_CONTAINS_EDGE)
    e_success = (adde & eligible & ~e_valid) | ((reme | cone) & e_valid)

    ecap = state.e_key_u.shape[0]
    ewr = (adde | reme) & e_success & eloc.found
    ewslot = jnp.where(ewr, eloc.slot, ecap)
    e_live_new = state.e_live.at[ewslot].set(adde & e_success, mode="drop")
    e_bu_new = state.e_inc_u.at[ewslot].set(u_inc, mode="drop")
    e_bv_new = state.e_inc_v.at[ewslot].set(v_inc, mode="drop")

    e_need_ins = adde & e_success & ~eloc.found
    e_ku_new, e_kv_new, e_new_slots, e_over, e_rounds = claim_edge_slots(
        state.e_key_u, state.e_key_v,
        jnp.where(e_need_ins, u, _INT32_MAX), jnp.where(e_need_ins, v, _INT32_MAX),
        e_need_ins,
    )
    eislot = jnp.where(e_need_ins & (e_new_slots >= 0), e_new_slots, ecap)
    e_live_new = e_live_new.at[eislot].set(True, mode="drop")
    e_bu_new = e_bu_new.at[eislot].set(u_inc, mode="drop")
    e_bv_new = e_bv_new.at[eislot].set(v_inc, mode="drop")

    state = state._replace(
        e_key_u=e_ku_new, e_key_v=e_kv_new,
        e_live=e_live_new, e_inc_u=e_bu_new, e_inc_v=e_bv_new,
    )
    n_ins = jnp.sum(e_need_ins & (e_new_slots >= 0)).astype(jnp.int32)
    return state, e_success, eloc.overflow | e_over, n_ins, e_rounds


@jax.jit
def settle_edges_fpsp(
    state: GraphState,
    batch: OpBatch,
    u_live: jnp.ndarray,
    u_inc: jnp.ndarray,
    v_live: jnp.ndarray,
    v_inc: jnp.ndarray,
):
    """FPSP twin of :func:`repro.core.engine.settle_edges` for the
    partitioned pipeline: edge ops whose ``(u, v)`` is unique in this
    shard's sub-batch take the sort-free direct path (the stab answers
    stand in for the endpoint table reads), and only duplicate-key groups
    pay the phase-ordered epoch scan.  Returns ``(state', results,
    overflow, stats)`` with ``stats`` = ``i32[4]: [n_edge_dup, n_inserted,
    claim_rounds, n_eops]`` (same layout as
    :func:`repro.core.engine.settle_edges`, so the sharded pipeline unpacks
    both identically) — exactly the FPSP conflict semantics on the
    sub-batch."""
    op = batch.op
    is_eop = (op == OP_ADD_EDGE) | (op == OP_REMOVE_EDGE) | (op == OP_CONTAINS_EDGE)
    conflicted = is_eop & _edge_dup_mask(batch.u, batch.v, is_eop)
    fast = is_eop & ~conflicted
    endpoint = (u_live, u_inc, v_live, v_inc)

    state, fast_success, fast_over, fast_ins, fast_rounds = _fast_apply_edges(
        state, batch, fast, endpoint
    )

    n_conf = jnp.sum(conflicted).astype(jnp.int32)

    def slow(st):
        masked = batch._replace(op=jnp.where(conflicted, batch.op, OP_NOP))
        is_eop_m = conflicted
        return engine._edge_wave(st, masked, is_eop_m, endpoint)

    def skip(st):
        return (
            st,
            jnp.zeros((batch.size,), bool),
            jnp.array(False),
            jnp.int32(0),
            jnp.int32(0),
        )

    state, slow_success, slow_over, slow_ins, slow_rounds = jax.lax.cond(
        n_conf > 0, slow, skip, state
    )
    success = jnp.where(fast, fast_success, slow_success)
    stats = jnp.stack(
        [
            n_conf,
            fast_ins + slow_ins,
            fast_rounds + slow_rounds,
            jnp.sum(is_eop).astype(jnp.int32),
        ]
    )
    return state, success, fast_over | slow_over, stats


@jax.jit
def apply_batch_fpsp(state: GraphState, batch: OpBatch) -> ApplyResult:
    """Fast-path-slow-path: vectorized direct apply for conflict-free ops,
    full wait-free engine only for the conflicted remainder."""
    conflicted, is_vop, is_eop, v_conf, e_conf, edge_dup = _conflict_mask(batch)
    fast = (is_vop | is_eop) & ~conflicted

    state, fast_success, fast_over, fast_ins, fast_rounds = _fast_apply(
        state, batch, fast
    )

    # slow path: mask fast ops to NOP; cond skips it when nothing conflicts
    n_conf = jnp.sum(conflicted).astype(jnp.int32)

    def slow(state_and_batch):
        st, b = state_and_batch
        masked = b._replace(op=jnp.where(conflicted, b.op, OP_NOP))
        return engine.apply_batch(st, masked)

    def skip(state_and_batch):
        st, b = state_and_batch
        return ApplyResult(
            state=st,
            success=jnp.zeros((b.size,), bool),
            ok=jnp.array(True),
            stats=jnp.zeros((types.N_STATS,), jnp.int32),
        )

    res = jax.lax.cond(n_conf > 0, slow, skip, (state, batch))

    success = jnp.where(fast, fast_success, res.success)
    # stats (see types.STAT_*): the slow engine's inserted/rounds counters
    # accumulate with the fast lane's; the conflict split and the lane
    # totals are full-batch quantities, so they overwrite the masked-batch
    # values the slow pass saw
    stats = res.stats
    stats = stats.at[types.STAT_CONFLICTED].set(n_conf)
    stats = stats.at[types.STAT_V_CONFLICTS].set(jnp.sum(v_conf).astype(jnp.int32))
    stats = stats.at[types.STAT_E_CONFLICTS].set(jnp.sum(e_conf).astype(jnp.int32))
    stats = stats.at[types.STAT_INSERTED].add(fast_ins)
    stats = stats.at[types.STAT_EDGE_DUP].set(jnp.sum(edge_dup).astype(jnp.int32))
    stats = stats.at[types.STAT_VOPS].set(jnp.sum(is_vop).astype(jnp.int32))
    stats = stats.at[types.STAT_EOPS].set(jnp.sum(is_eop).astype(jnp.int32))
    stats = stats.at[types.STAT_CLAIM_ROUNDS].add(fast_rounds)
    return ApplyResult(
        state=res.state, success=success, ok=res.ok & ~fast_over, stats=stats
    )
