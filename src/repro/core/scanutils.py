"""Segmented-scan building blocks for the wait-free combine engine.

The paper's helping mechanism ("every thread applies every pending op with a
lower phase") becomes, on a vector machine, function composition along the
phase-sorted op sequence.  Both DFAs involved are tiny:

* vertex liveness: 2-state machine {dead, live}; transitions are const/id,
  represented as a pair ``(f(dead), f(live))`` — composition is associative.
* per-epoch edge validity: 1-bit machine, same representation.

Because every segment head is replaced by ``f_head ∘ const(seed)`` (a constant
function), composition across segment boundaries collapses automatically and a
plain ``lax.associative_scan`` resolves *all* segments in O(log n) depth with
no explicit reset flags.  That O(log n) bound — independent of how contended
any single key is — is the dataflow analogue of wait-freedom.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compose_fnpair(a, b):
    """Compose 2-state transition functions b∘a.

    Elements are pairs (f0, f1) = (f(state=0), f(state=1)), int32 in {0,1}.
    lax.associative_scan applies ``fn(prev, next)`` so the scan computes
    ``next ∘ prev`` — exactly phase order when the array is phase-sorted.
    """
    a0, a1 = a
    b0, b1 = b
    # (b∘a)(s) = b(a(s)); a(s) ∈ {0,1} selects b0/b1.
    c0 = jnp.where(a0 == 1, b1, b0)
    c1 = jnp.where(a1 == 1, b1, b0)
    return (c0, c1)


def scan_fnpairs(f0: jnp.ndarray, f1: jnp.ndarray):
    """Inclusive scan of function-pair composition along axis 0."""
    return jax.lax.associative_scan(compose_fnpair, (f0, f1))


def last_set_combine(a, b):
    """Monoid: keep the most recent element whose ``set`` flag is true.

    Elements are (payload_pytree, set_flag).  Used for the stabbing query
    ("what was vertex u's (live, inc) at phase p?") — queries are unset
    elements that read through to the last transition before them.
    """
    pa, fa = a
    pb, fb = b
    out = jax.tree.map(lambda x, y: jnp.where(fb, y, x), pa, pb)
    return (out, fa | fb)


def scan_last_set(payload, set_flag: jnp.ndarray):
    """Inclusive last-set scan along axis 0. payload: pytree of [n,...] arrays."""
    return jax.lax.associative_scan(last_set_combine, (payload, set_flag))


def seg_cumsum_exclusive(x: jnp.ndarray, heads: jnp.ndarray) -> jnp.ndarray:
    """Exclusive cumulative sum within segments delimited by ``heads``.

    heads[i] == True marks the first element of a segment.
    """

    def combine(a, b):
        va, fa = a
        vb, fb = b
        return (jnp.where(fb, vb, va + vb), fa | fb)

    incl, _ = jax.lax.associative_scan(combine, (x, heads))
    return incl - x


def shift_right(x: jnp.ndarray, fill) -> jnp.ndarray:
    """x[i-1] with x[0] = fill (for 'value at previous sorted position')."""
    return jnp.concatenate([jnp.full((1,) + x.shape[1:], fill, dtype=x.dtype), x[:-1]], axis=0)
