"""Host-side wrapper: the *unbounded* wait-free graph.

``WaitFreeGraph`` owns the functional :class:`GraphState` plus the global
phase counter (the paper's ``maxPhase`` fetch-and-add — here a host-side
monotone counter; each batch gets ``counter + iota`` stamps, and the counter
advances by the batch size).  "Unbounded" is realised exactly as the paper's
``new VNode(...)``: amortized growth.  Every engine pass is *transactional* —
if any bounded probe chain or insert round tripped its cap (``ok == False``),
the post-state is discarded, the tables are grown (rehash = Harris physical
deletion: tombstones and stale edges are dropped), and the same batch is
re-applied against the grown pre-state.  Results are therefore exact
regardless of when growth happens.

Deterministic by construction: given the same op stream, every host/device
computes the identical table — this is what the serving engine relies on for
coordination-free multi-host page tables.

Telemetry (``obs=`` / ``REPRO_OBS``) hangs off every public entry point:
per-phase spans, fast-path/claim-round counters, growth events — all derived
from stats the jitted passes compute anyway, so enabling it never perturbs
results.  Metric catalog and overhead contract: ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# obs.metrics imports nothing from repro.core, so this is cycle-free even
# though repro.core.__init__ imports this module (see repro.obs docstring)
from ..obs import metrics as obsm
from . import engine, fastpath, maintenance, sharding, traversal
from .types import (
    EDGE_OPS,
    EMPTY_KEY,
    GROW_LOAD_FACTOR,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    STAT_CLAIM_ROUNDS,
    STAT_CONFLICTED,
    STAT_E_CONFLICTS,
    STAT_EDGE_DUP,
    STAT_EOPS,
    STAT_INSERTED,
    STAT_V_CONFLICTS,
    STAT_VOPS,
    GraphState,
    OpBatch,
    is_pow2,
    make_batch,
    make_state,
)

_INT32_MAX = np.iinfo(np.int32).max

_MAX_GROW_ATTEMPTS = 12

_MUTATING_OPS = (OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_ADD_EDGE, OP_REMOVE_EDGE)


def _bucket_size(n: int) -> int:
    """Power-of-two batch bucket (floor 64), shared by ``apply`` and its
    sharded twin: the sharded-vs-1-shard byte-identity contract requires
    identical padding and phase stamps in both paths, so there is exactly
    one definition of the bucket rule."""
    return max(64, 1 << max(n - 1, 1).bit_length())


@jax.jit
def _live_counts(state: GraphState):
    v = jnp.sum(state.v_live)
    e = jnp.sum(state.e_live)
    v_used = jnp.sum(state.v_key != EMPTY_KEY)
    e_used = jnp.sum(state.e_key_u != EMPTY_KEY)
    return v, e, v_used, e_used


def _rehash_escalating(
    state: GraphState,
    new_vcap: int,
    new_ecap: int,
    impl: Optional[str] = None,
    with_csr: bool = False,
):
    """The grow-and-retry discipline shared by :func:`_rehash` and
    ``WaitFreeGraph._grow``: placement is bounded by the engines' own
    ``MAX_PROBES``, so should a chain overflow it (a key the engines could
    never locate again), the capacities double and the compaction retries.
    Returns ``(new_state, csr_or_None)``."""
    for attempt in range(_MAX_GROW_ATTEMPTS):
        new_state, csr, ok = maintenance.rehash(
            state, new_vcap, new_ecap, impl=impl, with_csr=with_csr
        )
        if ok:
            return new_state, csr
        # escalation: placement overflowed even at the doubled capacity —
        # rare enough to log as a structured event, not just a counter
        obsm.counter("growth.escalations")
        obsm.event(
            "growth.escalation",
            attempt=attempt,
            v_capacity=new_vcap,
            e_capacity=new_ecap,
        )
        new_vcap *= 2
        new_ecap *= 2
    raise RuntimeError("rehash placement did not converge")


def _rehash(
    state: GraphState, new_vcap: int, new_ecap: int, impl: Optional[str] = None
) -> GraphState:
    """Grow + compact: keep live vertices (with incarnations) and valid live
    edges only — the batched analogue of Harris physical deletion.

    Stable entry point over :func:`repro.core.maintenance.rehash` (which
    owns the host/device implementations), with capacity escalation on
    placement overflow."""
    return _rehash_escalating(state, new_vcap, new_ecap, impl)[0]


class WaitFreeGraph:
    """The unbounded concurrent graph: the paper's public API, batched.

    ``mode`` selects the engine:
      * ``"waitfree"`` — full phase-ordered helping pass (paper §3).
      * ``"fpsp"``     — fast-path-slow-path (paper §3.4): conflict-free ops
        take a sort-free vectorized path; only conflicted ops pay the scans.

    ``traversal_impl`` selects the frontier-expansion backend for every
    traversal query (``None`` = auto: Pallas kernel on TPU, pure-jnp
    reference elsewhere; ``"kernel"`` / ``"kernel_interpret"`` /
    ``"reference"`` force one — see :mod:`repro.kernels.frontier`).

    ``csr_maintenance`` picks what happens to a cached traversal snapshot
    when an update batch lands: ``"delta"`` folds the batch into it with
    :func:`repro.core.traversal.apply_delta` (bit-identical to a rebuild,
    O(batch) instead of O(capacity) — the win for update-light query-heavy
    mixes), ``"rebuild"`` discards it and recompacts lazily on next query.

    ``maintenance_impl`` selects where table maintenance (growth rehash and
    the ``apply_delta`` splice) runs: ``"device"`` routes both through
    :mod:`repro.core.maintenance` (the :mod:`repro.kernels.compact`
    sort + prefix-sum pipeline; a growth rehash also pre-compacts the
    traversal snapshot so the post-growth ``build_csr`` is one delta fold),
    ``"device_interpret"`` forces the Pallas kernels through the
    interpreter, ``"host"`` keeps the vectorized-numpy oracle.  ``None`` =
    auto: device on TPU, host elsewhere.  All impls produce bit-identical
    tables, so the flag is purely a performance knob.

    ``obs`` enables wait-free telemetry (:mod:`repro.obs`): ``None`` defers
    to the ``REPRO_OBS`` env var, ``True`` attaches a fresh
    :class:`repro.obs.Registry`, ``False`` forces the zero-cost no-op, and
    a registry instance is shared as-is.  Every metric is derived from
    arrays the jitted programs compute regardless, so the flag never
    changes graph state or query answers (bit-identity pinned by
    ``tests/test_obs.py``); catalog in ``docs/OBSERVABILITY.md``.

    ``n_shards`` hash-prefix-partitions *both* tables into that many
    per-shard states — each shard owns ``1/n_shards`` of the vertex key
    space and of the edge key space (O(N/S) memory per shard), with ops
    routed by the prefix of the hash the probe sequence already uses and a
    cross-shard stabbing wave answering endpoint liveness between the
    vertex and edge settlement phases (see :mod:`repro.core.sharding`) —
    round-robined over ``mesh`` (default: a host-local
    :func:`repro.core.sharding.host_local_mesh`).  ``n_shards=1`` (the
    default) bypasses the routing layer entirely; any shard count produces
    identical query answers (pinned by ``tests/test_sharding.py``), so the
    flag is a pure scaling knob.  The incremental ``csr_maintenance=
    "delta"`` fold applies to 1-shard graphs only; sharded snapshots are
    rebuilt via :func:`repro.core.sharding.fuse_partitioned` on demand.
    """

    def __init__(
        self,
        v_capacity: int = 1024,
        e_capacity: int = 4096,
        mode: str = "waitfree",
        traversal_impl: Optional[str] = None,
        csr_maintenance: str = "delta",
        maintenance_impl: Optional[str] = None,
        n_shards: int = 1,
        mesh=None,
        obs=None,
    ):
        assert mode in ("waitfree", "fpsp")
        assert csr_maintenance in ("delta", "rebuild")
        assert maintenance_impl in maintenance.MAINTENANCE_IMPLS
        assert is_pow2(n_shards), "n_shards must be a power of two"
        self._csr: Optional[traversal.TraversalCSR] = None  # cached snapshot
        self._grow_csr: Optional[traversal.TraversalCSR] = None
        self.n_shards = n_shards
        self._mesh = None
        if n_shards == 1:
            self.state = make_state(v_capacity, e_capacity)
        else:
            assert e_capacity % n_shards == 0 and is_pow2(e_capacity // n_shards), (
                "e_capacity must split into power-of-two per-shard capacities"
            )
            assert v_capacity % n_shards == 0 and is_pow2(v_capacity // n_shards), (
                "v_capacity must split into power-of-two per-shard capacities"
            )
            self._mesh = mesh if mesh is not None else sharding.host_local_mesh()
            self.shards = sharding.place_shards(
                sharding.make_shard_states(
                    v_capacity // n_shards, e_capacity // n_shards, n_shards
                ),
                self._mesh,
            )
        self.mode = mode
        self.traversal_impl = traversal_impl
        self.csr_maintenance = csr_maintenance
        self.maintenance_impl = maintenance_impl
        self.obs = obsm.resolve(obs)
        self._phase = 0  # the paper's maxPhase counter

    @property
    def state(self) -> GraphState:
        if self.n_shards > 1:
            raise AttributeError(
                "sharded graph: per-shard states live on .shards "
                "(both tables are hash-prefix partitions)"
            )
        return self._state

    @state.setter
    def state(self, value: GraphState) -> None:
        # any state swap (apply, growth, or a caller installing a rehashed
        # state directly) invalidates the cached traversal snapshot AND any
        # pending delta queue (its base snapshot no longer matches the state)
        self._state = value
        self._csr = None
        self._delta_base = None
        self._delta_batches = []

    @property
    def shards(self) -> List[GraphState]:
        return self._shards

    @shards.setter
    def shards(self, value) -> None:
        # same invalidation contract as the ``state`` setter (the fused
        # snapshot is rebuilt from scratch — the delta fold is 1-shard only)
        self._shards = list(value)
        self._csr = None
        self._delta_base = None
        self._delta_batches = []

    # -- batched API ------------------------------------------------------
    def apply(self, ops, us, vs=None) -> np.ndarray:
        """Apply a batch; returns bool[n] success per op (phase order = batch
        order).

        Batches are padded to power-of-two buckets with NOP lanes: the jitted
        engines specialize on batch size, and a serving workload publishes a
        different op count every step — unbucketed, that is a recompile per
        step (measured 1.09 s/step vs ~ms after bucketing)."""
        n = len(ops)
        if n == 0:
            # nothing to resolve: skip the padded engine dispatch entirely
            return np.zeros(0, bool)
        # read-only batches (contains/NOP only) leave the abstract graph
        # unchanged, so the cached traversal snapshot stays valid — keep it
        # across the state swap below instead of forcing a CSR rebuild.
        ops0 = np.asarray(ops, np.int32)
        us0 = np.asarray(us, np.int32)
        vs0 = np.zeros_like(us0) if vs is None else np.asarray(vs, np.int32)
        reg = self.obs
        with obsm.use(reg):
            reg.counter("apply.batches")
            reg.counter("apply.ops", n)
            reg.hist("apply.batch_size", n)
            if self.n_shards > 1:
                with reg.span("graph.apply_sharded"):
                    return self._apply_sharded(ops0, us0, vs0)
            with reg.span("graph.apply"):
                return self._apply_dense(ops0, us0, vs0)

    def _apply_dense(self, ops0, us0, vs0) -> np.ndarray:
        """The ``n_shards == 1`` engine dispatch behind :meth:`apply` (runs
        inside the obs ``use`` scope the wrapper installed)."""
        n = ops0.shape[0]
        mutating = bool(np.isin(ops0, _MUTATING_OPS).any())
        saved_csr = None if mutating else self._csr
        # the pending-delta queue (base snapshot + unpadded batches since the
        # last query) survives the state swap below: read-only batches carry
        # it unchanged, mutating batches append to it so the next query folds
        # the whole queue in one apply_delta (lazy: an update-heavy stream
        # between queries pays nothing per batch, one fold per query epoch)
        delta_base, delta_batches = self._delta_base, self._delta_batches
        if mutating and self.csr_maintenance == "delta" and self._csr is not None:
            delta_base, delta_batches = self._csr, []
        bucket = _bucket_size(n)
        ops, us, vs = ops0, us0, vs0
        if bucket != n:
            pad = np.zeros(bucket - n, np.int32)  # OP_NOP = 0
            ops = np.concatenate([ops0, pad])
            us = np.concatenate([us0, pad])
            vs = np.concatenate([vs0, pad])
        batch = make_batch(ops, us, vs, phase_base=self._phase)
        self._phase += batch.size
        apply_fn = engine.apply_batch if self.mode == "waitfree" else fastpath.apply_batch_fpsp

        self._grow_csr = None
        for attempt in range(_MAX_GROW_ATTEMPTS):
            # keep the pre-state alive for transactional retry
            pre = self.state
            res = apply_fn(pre, batch)
            if bool(res.ok) and not self._needs_growth(res.state):
                # the successful attempt alone feeds the obs counters —
                # discarded growth attempts re-run the same lanes and would
                # double-count them
                if self.obs.enabled:
                    self._record_engine_stats(self.obs, res.stats)
                grow_csr = self._grow_csr
                self.state = res.state
                if attempt > 0:
                    # growth rehashed the tables: every slot moved, so both
                    # the saved snapshot's and the queue's bases are void —
                    # the state setter already dropped them.  The rehash
                    # pre-compacted the grown state's snapshot, though
                    # (maintenance "snapshot-compact"): queue this batch
                    # against it so the next query pays one delta fold, not
                    # a full rebuild.
                    if (
                        mutating
                        and grow_csr is not None
                        and self.csr_maintenance == "delta"
                    ):
                        self._delta_base = grow_csr
                        self._delta_batches = [(ops0, us0, vs0)]
                    return np.asarray(res.success)[:n]
                if not mutating:
                    # abstractly identical pre/post state: the saved snapshot
                    # (own references to the old tables) and any pending
                    # queue stay exactly as valid as before the batch
                    self._csr = saved_csr
                    self._delta_base = delta_base
                    self._delta_batches = delta_batches
                elif delta_base is not None and self.csr_maintenance == "delta":
                    # queue the batch against the remembered base snapshot;
                    # traversal_csr() folds the queue on the next query.  A
                    # queue past the fold's own fallback threshold would
                    # rebuild anyway — drop it and stop accumulating.
                    delta_batches = delta_batches + [(ops0, us0, vs0)]
                    if sum(b[0].size for b in delta_batches) > delta_base.e_capacity // 4:
                        delta_base, delta_batches = None, []
                    self._delta_base = delta_base
                    self._delta_batches = delta_batches
                return np.asarray(res.success)[:n]
            # discard post-state; grow from pre-state; retry the same batch
            self.state = self._grow(pre)
        raise RuntimeError("graph growth did not converge")

    def _record_engine_stats(self, reg, stats) -> None:
        """Fold one successful engine pass's stats vector (types.STAT_*)
        into the registry — the single host-side device read obs adds, and
        only when a live registry is attached."""
        s = [int(x) for x in np.asarray(stats)]
        reg.counter("engine.inserted", s[STAT_INSERTED])
        reg.counter("engine.vops", s[STAT_VOPS])
        reg.counter("engine.eops", s[STAT_EOPS])
        reg.hist("engine.claim_rounds", s[STAT_CLAIM_ROUNDS])
        if self.mode == "fpsp":
            reg.counter("fastpath.ops", s[STAT_VOPS] + s[STAT_EOPS])
            reg.counter("fastpath.vops", s[STAT_VOPS])
            reg.counter("fastpath.eops", s[STAT_EOPS])
            reg.counter("fastpath.conflicted", s[STAT_CONFLICTED])
            reg.counter("fastpath.vertex_conflicts", s[STAT_V_CONFLICTS])
            reg.counter("fastpath.edge_conflicts", s[STAT_E_CONFLICTS])
            reg.counter("fastpath.edge_dup", s[STAT_EDGE_DUP])
            reg.counter(
                "fastpath.slow_batches" if s[STAT_CONFLICTED] else "fastpath.fast_batches"
            )

    def _record_sharded_stats(self, reg, v_stats, e_stats) -> None:
        """Per-shard twin of :meth:`_record_engine_stats`: fold the
        ``settle_vertices``/``settle_edges`` stats vectors of one successful
        sharded attempt.  The edge-lane fastpath counters sum to the same
        totals for any shard count (duplicate ``(u, v)`` lanes co-locate on
        one shard) — the shard-invariance ``tests/test_obs.py`` pins."""
        for v_st, e_st in zip(v_stats, e_stats):
            v_ins, v_rounds, n_vops = (int(x) for x in np.asarray(v_st))
            e_dup, e_ins, e_rounds, n_eops = (int(x) for x in np.asarray(e_st))
            reg.counter("engine.inserted", v_ins + e_ins)
            reg.counter("engine.vops", n_vops)
            reg.counter("engine.eops", n_eops)
            reg.hist("engine.claim_rounds", v_rounds + e_rounds)
            if self.mode == "fpsp":
                reg.counter("fastpath.eops", n_eops)
                reg.counter("fastpath.edge_dup", e_dup)
                reg.counter(
                    "fastpath.slow_batches" if e_dup else "fastpath.fast_batches"
                )

    def _needs_growth(self, state: GraphState) -> bool:
        v, e, v_used, e_used = _live_counts(state)
        return bool(v_used > GROW_LOAD_FACTOR * state.v_capacity) or bool(
            e_used > GROW_LOAD_FACTOR * state.e_capacity
        )

    def _grow(self, state: GraphState) -> GraphState:
        v, e, v_used, e_used = _live_counts(state)
        new_vcap = state.v_capacity
        new_ecap = state.e_capacity
        # grow whichever table is crowded (or both); compaction alone can be
        # enough when tombstones dominate, but doubling keeps it simple and
        # amortized-O(1).
        if int(v_used) > GROW_LOAD_FACTOR * state.v_capacity / 2:
            new_vcap *= 2
        if int(e_used) > GROW_LOAD_FACTOR * state.e_capacity / 2:
            new_ecap *= 2
        if new_vcap == state.v_capacity and new_ecap == state.e_capacity:
            new_vcap *= 2
            new_ecap *= 2
        impl = maintenance.resolve_impl(self.maintenance_impl)
        if self.obs.enabled:
            self.obs.counter("growth.events")
            self.obs.event(
                "growth.grow",
                v_before=state.v_capacity,
                v_after=new_vcap,
                e_before=state.e_capacity,
                e_after=new_ecap,
                v_live=int(v),
                e_live=int(e),
            )
        # snapshot-compact rides the device pass nearly free; on the host it
        # would be an eager build_csr per grow attempt — leave that lazy
        with_csr = impl != "host" and self.csr_maintenance == "delta"
        new_state, csr = _rehash_escalating(state, new_vcap, new_ecap, impl, with_csr)
        # stashed for apply(): becomes the delta base of the retried batch
        # (the state setter must not clear it — the grown state is installed
        # right after this returns)
        self._grow_csr = csr
        return new_state

    # -- hash-prefix sharded apply (see repro.core.sharding) ----------------

    @staticmethod
    def _sub_batch(ops0, us0, vs0, phases0, idx) -> OpBatch:
        """Compact one shard's owned lanes into a pow2-bucketed sub-batch.
        Lanes keep their *global* phase stamps (linearization = batch
        order, shard-count-independent); padding lanes are NOPs, inert in
        every wave (their keys sort to the INT32_MAX sentinel)."""
        m = idx.size
        bucket = _bucket_size(m)
        op = np.zeros(bucket, np.int32)
        u = np.zeros(bucket, np.int32)
        v = np.zeros(bucket, np.int32)
        ph = np.zeros(bucket, np.int32)
        op[:m] = ops0[idx]
        u[:m] = us0[idx]
        v[:m] = vs0[idx]
        ph[:m] = phases0[idx]
        return OpBatch(
            op=jnp.asarray(op), u=jnp.asarray(u), v=jnp.asarray(v),
            phase=jnp.asarray(ph),
        )

    def _apply_sharded(self, ops0, us0, vs0) -> np.ndarray:
        """The n_shards > 1 twin of ``apply``: the partitioned three-phase
        pipeline (route → vertex settle → stab → gather → edge claim).

        Each shard receives only its owned lanes (O(batch/S) sub-batches —
        no silhouette replication), so the phases are explicit:

          A. ``settle_vertices`` per shard — each shard's vertex wave over
             its owned vertex ops, returning per-lane transition payloads;
          B. ``answer_stabs`` per endpoint-owner shard — every edge lane's
             two (endpoint, phase) queries are routed to the endpoint's
             owner, answered against its transitions + pre-batch table,
             and gathered host-side (the all-to-all exchange);
          C. ``settle_edges`` (or its FPSP twin) per shard — the unchanged
             edge wave over owned edge ops, fed the gathered answers.

        Linearization is unchanged: lanes carry globally unique phase
        stamps, every vertex op on a key lives on one shard (so its
        transition sequence is complete there), and the stab answers are
        exactly what the monolithic engine's in-batch stabbing wave would
        have computed.  Growth is transactional per attempt, as in
        ``apply``: any overflow discards the post-states, grows from the
        pre-states, and re-runs the same batch at the same phases."""
        n = ops0.shape[0]
        S = self.n_shards
        reg = self.obs
        mutating = bool(np.isin(ops0, _MUTATING_OPS).any())
        saved_csr = None if mutating else self._csr
        with reg.span("phase.route"):
            shard_idx, _ = sharding.route_ops(ops0, us0, vs0, S)
            phases0 = (self._phase + np.arange(n)).astype(np.int32)
            self._phase += n
            batches = [
                self._sub_batch(ops0, us0, vs0, phases0, idx) for idx in shard_idx
            ]
        if reg.enabled:
            sizes = [int(idx.size) for idx in shard_idx]
            reg.hist("shard.subbatch_size", sizes)
            if sum(sizes):
                # max-over-mean routed load: 1.0 = perfectly balanced
                reg.gauge("shard.balance", max(sizes) * S / sum(sizes))

        # stab queries: two (endpoint, phase) probes per edge lane, routed
        # to the endpoint's owner shard (fixed across growth attempts —
        # growth preserves the abstract graph, so answers are identical)
        eidx = np.flatnonzero(np.isin(ops0, EDGE_OPS))
        ne = eidx.size
        q_keys = np.concatenate([us0[eidx], vs0[eidx]]).astype(np.int32)
        q_phases = np.concatenate([phases0[eidx], phases0[eidx]])
        q_owner = sharding.shard_of_vertices(q_keys, S)
        q_sel = [np.flatnonzero(q_owner == t) for t in range(S)]
        if reg.enabled:
            reg.counter("stab.queries", 2 * ne)
            reg.hist("shard.stab_fanout", [int(sel.size) for sel in q_sel])
        q_pads = [
            (
                traversal._pad_pow2(q_keys[sel], _INT32_MAX),
                traversal._pad_pow2(q_phases[sel], 0),
            )
            for sel in q_sel
        ]
        settle_edges_fn = (
            engine.settle_edges if self.mode == "waitfree"
            else fastpath.settle_edges_fpsp
        )

        for _attempt in range(_MAX_GROW_ATTEMPTS):
            pre = self._shards  # kept alive for transactional retry
            ok = True

            # A. vertex settlement per shard
            with reg.span("phase.settle_vertices"):
                states_a, v_res, evs, v_stats = [], [], [], []
                for s in range(S):
                    st, res, ev_l, ev_i, over, v_st = engine.settle_vertices(
                        pre[s], batches[s]
                    )
                    ok &= not bool(over)
                    states_a.append(st)
                    v_res.append(res)
                    evs.append((ev_l, ev_i))
                    v_stats.append(v_st)

            # B. stabbing wave: owner shards answer, host gathers
            with reg.span("phase.answer_stabs"):
                q_live = np.zeros(2 * ne, bool)
                q_inc = np.zeros(2 * ne, np.int32)
                for t in range(S):
                    sel = q_sel[t]
                    if sel.size == 0:
                        continue
                    qk, qp = q_pads[t]
                    live, inc, over = engine.answer_stabs(
                        pre[t], batches[t], evs[t][0], evs[t][1],
                        jnp.asarray(qk), jnp.asarray(qp),
                    )
                    ok &= not bool(over)
                    q_live[sel] = np.asarray(live)[: sel.size]
                    q_inc[sel] = np.asarray(inc)[: sel.size]
            with reg.span("phase.gather"):
                u_live = np.zeros(n, bool)
                u_inc = np.zeros(n, np.int32)
                v_live = np.zeros(n, bool)
                v_inc = np.zeros(n, np.int32)
                u_live[eidx] = q_live[:ne]
                u_inc[eidx] = q_inc[:ne]
                v_live[eidx] = q_live[ne:]
                v_inc[eidx] = q_inc[ne:]

            # C. edge settlement per shard, fed the gathered answers
            with reg.span("phase.settle_edges"):
                out = np.zeros(n, bool)
                states_c, e_stats = [], []
                for s in range(S):
                    idx = shard_idx[s]
                    m = idx.size
                    bucket = batches[s].size
                    ul = np.zeros(bucket, bool)
                    ui = np.zeros(bucket, np.int32)
                    vl = np.zeros(bucket, bool)
                    vi = np.zeros(bucket, np.int32)
                    ul[:m] = u_live[idx]
                    ui[:m] = u_inc[idx]
                    vl[:m] = v_live[idx]
                    vi[:m] = v_inc[idx]
                    st, e_res, over, e_st = settle_edges_fn(
                        states_a[s], batches[s],
                        jnp.asarray(ul), jnp.asarray(ui),
                        jnp.asarray(vl), jnp.asarray(vi),
                    )
                    ok &= not bool(over)
                    states_c.append(st)
                    e_stats.append(e_st)
                    if m:
                        out[idx] = (
                            np.asarray(v_res[s])[:m] | np.asarray(e_res)[:m]
                        )

            if ok and not self._needs_growth_sharded(states_c):
                self.shards = states_c
                # successful attempt only — retried attempts would
                # double-count lanes (see _apply_dense)
                if reg.enabled:
                    self._record_sharded_stats(reg, v_stats, e_stats)
                if not mutating:
                    # abstractly identical pre/post state: the cached fused
                    # snapshot stays exactly as valid as before the batch
                    self._csr = saved_csr
                return out
            with reg.span("phase.compact"):
                self.shards = self._grow_shards(pre)
        raise RuntimeError("graph growth did not converge")

    def _needs_growth_sharded(self, states: List[GraphState]) -> bool:
        counts = [_live_counts(st) for st in states]
        return any(
            bool(c[2] > GROW_LOAD_FACTOR * st.v_capacity)
            or bool(c[3] > GROW_LOAD_FACTOR * st.e_capacity)
            for c, st in zip(counts, states)
        )

    def _grow_shards(self, states: List[GraphState]) -> List[GraphState]:
        """Per-shard capacity policy: each shard doubles whichever of its
        tables is crowded (both key spaces are partitioned, so decisions
        are independent — no lockstep-replica constraint).  Edge validity
        during each rehash is judged against the *global* endpoint index
        (an edge's endpoints generally live on other shards); the
        escalation loop re-doubles only the shards whose placement
        overflowed."""
        counts = [_live_counts(st) for st in states]
        new_vcaps, new_ecaps = [], []
        for st, c in zip(states, counts):
            v_crowd = int(c[2]) > GROW_LOAD_FACTOR * st.v_capacity / 2
            e_crowd = int(c[3]) > GROW_LOAD_FACTOR * st.e_capacity / 2
            new_vcaps.append(2 * st.v_capacity if v_crowd else st.v_capacity)
            new_ecaps.append(2 * st.e_capacity if e_crowd else st.e_capacity)
        if all(vc == st.v_capacity for vc, st in zip(new_vcaps, states)) and all(
            ec == st.e_capacity for ec, st in zip(new_ecaps, states)
        ):
            # an engine-pass overflow with no crowded table: a pathological
            # probe chain somewhere — double everything, same as 1-shard
            new_vcaps = [2 * vc for vc in new_vcaps]
            new_ecaps = [2 * ec for ec in new_ecaps]
        impl = maintenance.resolve_impl(self.maintenance_impl)
        if self.obs.enabled:
            self.obs.counter("growth.events")
            self.obs.event(
                "growth.grow_shards",
                v_before=[st.v_capacity for st in states],
                v_after=list(new_vcaps),
                e_before=[st.e_capacity for st in states],
                e_after=list(new_ecaps),
            )
        endpoints = sharding.gather_live_vertices(states)
        for _ in range(_MAX_GROW_ATTEMPTS):
            outs = [
                maintenance.rehash(
                    st, vc, ec, impl=impl, with_csr=False, endpoints=endpoints
                )
                for st, vc, ec in zip(states, new_vcaps, new_ecaps)
            ]
            oks = [bool(ok) for _, _, ok in outs]
            if all(oks):
                return sharding.place_shards([s for s, _, _ in outs], self._mesh)
            self.obs.counter("growth.escalations")
            new_vcaps = [2 * vc if not ok else vc for vc, ok in zip(new_vcaps, oks)]
            new_ecaps = [2 * ec if not ok else ec for ec, ok in zip(new_ecaps, oks)]
        raise RuntimeError("rehash placement did not converge")

    # -- the paper's six-operation convenience API -------------------------
    def add_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_ADD_VERTEX], [u])[0])

    def remove_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_REMOVE_VERTEX], [u])[0])

    def contains_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_CONTAINS_VERTEX], [u])[0])

    def add_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_ADD_EDGE], [u], [v])[0])

    def remove_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_REMOVE_EDGE], [u], [v])[0])

    def contains_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_CONTAINS_EDGE], [u], [v])[0])

    # -- traversal queries (batched wait-free reachability) -----------------
    #
    # All queries run against one cached TraversalCSR snapshot — a compacted,
    # consistent view of the post-batch state.  The snapshot is rebuilt lazily
    # after any ``apply`` (the linearization point of every query in between
    # is that batch boundary, like the related papers' wait-free snapshots).

    def traversal_csr(self) -> traversal.TraversalCSR:
        """The cached consistent snapshot all queries linearize against.

        With ``csr_maintenance="delta"``, update batches queued since the
        last query are folded into the previous snapshot in one
        :func:`repro.core.traversal.apply_delta` call (result-blind
        reconciliation re-probes the union of touched keys against the
        *current* state, so one fold over many batches is exact); otherwise
        the snapshot is recompacted from scratch.

        Sharded graphs (``n_shards > 1``) rebuild the global snapshot from
        the partitioned shard states
        (:func:`repro.core.sharding.fuse_partitioned`): per-shard edge
        lanes are validated against the canonical global vertex directory
        and sorted into the one CSR every query linearizes against.  The
        incremental delta fold does not apply — per-shard slot spaces are
        private, so the directory (and with it every fused slot) can move
        on any vertex churn."""
        reg = self.obs
        if self.n_shards > 1:
            if self._csr is None:
                with obsm.use(reg), reg.span("csr.fuse"):
                    reg.counter("csr.fuse")
                    self._csr = sharding.fuse_partitioned(self._shards)
            return self._csr
        if self._csr is None:
            with obsm.use(reg):
                if self._delta_base is not None and self._delta_batches:
                    with reg.span("csr.delta_fold"):
                        reg.counter("csr.delta_fold")
                        self._csr = traversal.apply_delta(
                            self._delta_base,
                            self.state,
                            np.concatenate([b[0] for b in self._delta_batches]),
                            np.concatenate([b[1] for b in self._delta_batches]),
                            np.concatenate([b[2] for b in self._delta_batches]),
                            impl=self.maintenance_impl,
                        )
                else:
                    with reg.span("csr.build"):
                        reg.counter("csr.build")
                        self._csr = traversal.build_csr(self.state)
            self._delta_base = None
            self._delta_batches = []
        return self._csr

    @staticmethod
    def _pad_keys(keys: Sequence[int]) -> Tuple[np.ndarray, int]:
        """Pad a query key batch to a power-of-two bucket with EMPTY_KEY lanes
        (same recompile-avoidance trick as ``apply``'s NOP padding)."""
        arr = np.asarray(keys, np.int32)
        return traversal._pad_pow2(arr, int(EMPTY_KEY)), arr.shape[0]

    def reachable(self, us, vs) -> np.ndarray:
        """Batched directed reachability: bool[n], ``us[i] ↝ vs[i]``.

        False when either endpoint is absent; ``u ↝ u`` is True iff u exists
        (the empty path).  Scalars are accepted and return a plain bool."""
        scalar = np.isscalar(us)
        if scalar:
            us, vs = [us], [vs]
        if len(us) != len(vs):
            raise ValueError(f"reachable: {len(us)} sources vs {len(vs)} targets")
        pu, n = self._pad_keys(us)
        pv, _ = self._pad_keys(vs)
        self.obs.counter("query.reachable", n)
        out = np.asarray(
            traversal.reachable(self.traversal_csr(), pu, pv, impl=self.traversal_impl)
        )[:n]
        return bool(out[0]) if scalar else out

    def bfs(self, u: int) -> Dict[int, int]:
        """BFS level map from ``u``: {vertex_key: hop_distance}, ``u`` at 0.
        Empty when ``u`` is absent."""
        return self.bfs_batch([u])[0]

    def bfs_batch(self, sources: Sequence[int]) -> List[Dict[int, int]]:
        """Batched BFS: one level map per source, all against one snapshot."""
        pk, n = self._pad_keys(sources)
        csr = self.traversal_csr()
        levels = np.asarray(traversal.bfs_levels(csr, pk, impl=self.traversal_impl))[:n]
        if self.obs.enabled:
            # frontier iterations per source = deepest reached level (the
            # level map is computed regardless — obs only reduces it)
            self.obs.counter("query.bfs", n)
            self.obs.hist(
                "bfs.depth", [int(max(row.max(initial=0), 0)) for row in levels]
            )
        v_key = np.asarray(csr.v_key)
        out = []
        for row in levels:
            hit = np.nonzero(row >= 0)[0]
            out.append({int(v_key[j]): int(row[j]) for j in hit})
        return out

    def khop(self, u: int, k: int) -> Set[int]:
        """Vertex keys within ≤k directed hops of ``u`` (including ``u``)."""
        pk, _ = self._pad_keys([u])
        csr = self.traversal_csr()
        self.obs.counter("query.khop")
        mask = np.asarray(
            traversal.khop_mask(csr, pk, np.int32(k), impl=self.traversal_impl)
        )[0]
        v_key = np.asarray(csr.v_key)
        return {int(v_key[j]) for j in np.nonzero(mask)[0]}

    def get_path(self, u: int, v: int) -> Optional[List[int]]:
        """A shortest directed path ``u ↝ v`` as an explicit key list
        (``[u, ..., v]``; ``[u]`` when u == v), or ``None`` when unreachable
        or either endpoint is absent — the papers' ``GetPath``."""
        return self.get_path_batch([u], [v])[0]

    def get_path_batch(self, us, vs) -> List[Optional[List[int]]]:
        """Batched ``GetPath``: one shortest path (or None) per (u, v) pair,
        all answered against one snapshot.

        The device half (:func:`repro.core.traversal.path_probe`) records a
        parent slot per reached vertex as one extra scatter in the BFS level
        loop; the host walks the parent chain back from each target — at
        most one step per level, so reconstruction is O(path length)."""
        if len(us) != len(vs):
            raise ValueError(f"get_path_batch: {len(us)} sources vs {len(vs)} targets")
        pu, n = self._pad_keys(us)
        pv, _ = self._pad_keys(vs)
        csr = self.traversal_csr()
        self.obs.counter("query.get_path", n)
        levels, parents, vslot, vlive = (
            np.asarray(x)
            for x in traversal.path_probe(csr, pu, pv, impl=self.traversal_impl)
        )
        v_key = np.asarray(csr.v_key)
        out: List[Optional[List[int]]] = []
        for i in range(n):
            if not vlive[i] or levels[i, vslot[i]] < 0:
                out.append(None)
                continue
            chain = [int(vslot[i])]
            while levels[i, chain[-1]] > 0:
                chain.append(int(parents[i, chain[-1]]))
            out.append([int(v_key[s]) for s in reversed(chain)])
        return out

    # -- introspection ------------------------------------------------------
    def probe_health(self) -> Dict[str, Dict[int, int]]:
        """Physical probe-chain-length histograms over both hash tables
        (all shards), recorded into the graph's registry as ``probe.vertex``
        / ``probe.edge`` and returned — see :mod:`repro.obs.probes` for the
        derivation and its invariance properties."""
        from ..obs import probes

        return probes.record(self.obs, self)

    def snapshot(self) -> Tuple[set, set]:
        """Abstract (V, E) — for oracle comparison in tests.

        Vectorized: one device pass computes the live-vertex and
        incarnation-valid-edge masks (shared with the traversal engine's CSR
        validity predicate); host work is O(live), not O(capacity).

        Sharded graphs union the per-shard live-vertex partitions and
        validate every shard's edge lanes against the global sorted
        endpoint index (an edge's endpoints generally live on other
        shards)."""
        if self.n_shards > 1:
            sk, si = sharding.gather_live_vertices(self._shards)
            verts = set(sk.tolist())
            edges = set()
            if sk.size == 0:
                return verts, edges  # no live endpoints -> no valid edges
            for st in self._shards:
                e_live = np.asarray(st.e_live)
                eu = np.asarray(st.e_key_u)
                ev = np.asarray(st.e_key_v)
                fu, pu = sharding._lookup_sorted(sk, eu)
                fv, pv = sharding._lookup_sorted(sk, ev)
                valid = (
                    e_live
                    & fu
                    & fv
                    & (si[pu] == np.asarray(st.e_inc_u))
                    & (si[pv] == np.asarray(st.e_inc_v))
                )
                edges |= set(zip(eu[valid].tolist(), ev[valid].tolist()))
            return verts, edges
        v_mask, e_mask = traversal.snapshot_live(self.state)
        v_mask = np.asarray(v_mask)
        e_mask = np.asarray(e_mask)
        verts = set(np.asarray(self.state.v_key)[v_mask].tolist())
        eu = np.asarray(self.state.e_key_u)[e_mask].tolist()
        ev = np.asarray(self.state.e_key_v)[e_mask].tolist()
        return verts, set(zip(eu, ev))
