"""Host-side wrapper: the *unbounded* wait-free graph.

``WaitFreeGraph`` owns the functional :class:`GraphState` plus the global
phase counter (the paper's ``maxPhase`` fetch-and-add — here a host-side
monotone counter; each batch gets ``counter + iota`` stamps, and the counter
advances by the batch size).  "Unbounded" is realised exactly as the paper's
``new VNode(...)``: amortized growth.  Every engine pass is *transactional* —
if any bounded probe chain or insert round tripped its cap (``ok == False``),
the post-state is discarded, the tables are grown (rehash = Harris physical
deletion: tombstones and stale edges are dropped), and the same batch is
re-applied against the grown pre-state.  Results are therefore exact
regardless of when growth happens.

Deterministic by construction: given the same op stream, every host/device
computes the identical table — this is what the serving engine relies on for
coordination-free multi-host page tables.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import engine, fastpath, maintenance, sharding, traversal
from .types import (
    EMPTY_KEY,
    GROW_LOAD_FACTOR,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    GraphState,
    is_pow2,
    make_batch,
    make_state,
)

_MAX_GROW_ATTEMPTS = 12

_MUTATING_OPS = (OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_ADD_EDGE, OP_REMOVE_EDGE)


def _bucket_size(n: int) -> int:
    """Power-of-two batch bucket (floor 64), shared by ``apply`` and its
    sharded twin: the sharded-vs-1-shard byte-identity contract requires
    identical padding and phase stamps in both paths, so there is exactly
    one definition of the bucket rule."""
    return max(64, 1 << max(n - 1, 1).bit_length())


@jax.jit
def _live_counts(state: GraphState):
    v = jnp.sum(state.v_live)
    e = jnp.sum(state.e_live)
    v_used = jnp.sum(state.v_key != EMPTY_KEY)
    e_used = jnp.sum(state.e_key_u != EMPTY_KEY)
    return v, e, v_used, e_used


def _rehash_escalating(
    state: GraphState,
    new_vcap: int,
    new_ecap: int,
    impl: Optional[str] = None,
    with_csr: bool = False,
):
    """The grow-and-retry discipline shared by :func:`_rehash` and
    ``WaitFreeGraph._grow``: placement is bounded by the engines' own
    ``MAX_PROBES``, so should a chain overflow it (a key the engines could
    never locate again), the capacities double and the compaction retries.
    Returns ``(new_state, csr_or_None)``."""
    for _ in range(_MAX_GROW_ATTEMPTS):
        new_state, csr, ok = maintenance.rehash(
            state, new_vcap, new_ecap, impl=impl, with_csr=with_csr
        )
        if ok:
            return new_state, csr
        new_vcap *= 2
        new_ecap *= 2
    raise RuntimeError("rehash placement did not converge")


def _rehash(
    state: GraphState, new_vcap: int, new_ecap: int, impl: Optional[str] = None
) -> GraphState:
    """Grow + compact: keep live vertices (with incarnations) and valid live
    edges only — the batched analogue of Harris physical deletion.

    Stable entry point over :func:`repro.core.maintenance.rehash` (which
    owns the host/device implementations), with capacity escalation on
    placement overflow."""
    return _rehash_escalating(state, new_vcap, new_ecap, impl)[0]


class WaitFreeGraph:
    """The unbounded concurrent graph: the paper's public API, batched.

    ``mode`` selects the engine:
      * ``"waitfree"`` — full phase-ordered helping pass (paper §3).
      * ``"fpsp"``     — fast-path-slow-path (paper §3.4): conflict-free ops
        take a sort-free vectorized path; only conflicted ops pay the scans.

    ``traversal_impl`` selects the frontier-expansion backend for every
    traversal query (``None`` = auto: Pallas kernel on TPU, pure-jnp
    reference elsewhere; ``"kernel"`` / ``"kernel_interpret"`` /
    ``"reference"`` force one — see :mod:`repro.kernels.frontier`).

    ``csr_maintenance`` picks what happens to a cached traversal snapshot
    when an update batch lands: ``"delta"`` folds the batch into it with
    :func:`repro.core.traversal.apply_delta` (bit-identical to a rebuild,
    O(batch) instead of O(capacity) — the win for update-light query-heavy
    mixes), ``"rebuild"`` discards it and recompacts lazily on next query.

    ``maintenance_impl`` selects where table maintenance (growth rehash and
    the ``apply_delta`` splice) runs: ``"device"`` routes both through
    :mod:`repro.core.maintenance` (the :mod:`repro.kernels.compact`
    sort + prefix-sum pipeline; a growth rehash also pre-compacts the
    traversal snapshot so the post-growth ``build_csr`` is one delta fold),
    ``"device_interpret"`` forces the Pallas kernels through the
    interpreter, ``"host"`` keeps the vectorized-numpy oracle.  ``None`` =
    auto: device on TPU, host elsewhere.  All impls produce bit-identical
    tables, so the flag is purely a performance knob.

    ``n_shards`` hash-prefix-partitions the edge table into that many
    per-shard states (vertex table deterministically replicated, edge ops
    routed by the prefix of the hash the probe sequence already uses — see
    :mod:`repro.core.sharding`), round-robined over ``mesh`` (default: a
    host-local :func:`repro.core.sharding.host_local_mesh`).  ``n_shards=1``
    (the default) bypasses the routing layer entirely; any shard count
    produces byte-identical query results (pinned by
    ``tests/test_sharding.py``), so the flag is a pure scaling knob.
    """

    def __init__(
        self,
        v_capacity: int = 1024,
        e_capacity: int = 4096,
        mode: str = "waitfree",
        traversal_impl: Optional[str] = None,
        csr_maintenance: str = "delta",
        maintenance_impl: Optional[str] = None,
        n_shards: int = 1,
        mesh=None,
    ):
        assert mode in ("waitfree", "fpsp")
        assert csr_maintenance in ("delta", "rebuild")
        assert maintenance_impl in maintenance.MAINTENANCE_IMPLS
        assert is_pow2(n_shards), "n_shards must be a power of two"
        self._csr: Optional[traversal.TraversalCSR] = None  # cached snapshot
        self._grow_csr: Optional[traversal.TraversalCSR] = None
        self._grow_shard_csrs: Optional[List[traversal.TraversalCSR]] = None
        self._shard_csr_bases: Optional[List[traversal.TraversalCSR]] = None
        self.n_shards = n_shards
        self._mesh = None
        if n_shards == 1:
            self.state = make_state(v_capacity, e_capacity)
        else:
            assert e_capacity % n_shards == 0 and is_pow2(e_capacity // n_shards), (
                "e_capacity must split into power-of-two per-shard capacities"
            )
            self._mesh = mesh if mesh is not None else sharding.host_local_mesh()
            self.shards = sharding.place_shards(
                sharding.make_shard_states(v_capacity, e_capacity // n_shards, n_shards),
                self._mesh,
            )
        self.mode = mode
        self.traversal_impl = traversal_impl
        self.csr_maintenance = csr_maintenance
        self.maintenance_impl = maintenance_impl
        self._phase = 0  # the paper's maxPhase counter

    @property
    def state(self) -> GraphState:
        if self.n_shards > 1:
            raise AttributeError(
                "sharded graph: per-shard states live on .shards "
                "(vertex columns are replicas; edge tables are partitions)"
            )
        return self._state

    @state.setter
    def state(self, value: GraphState) -> None:
        # any state swap (apply, growth, or a caller installing a rehashed
        # state directly) invalidates the cached traversal snapshot AND any
        # pending delta queue (its base snapshot no longer matches the state)
        self._state = value
        self._csr = None
        self._delta_base = None
        self._delta_batches = []

    @property
    def shards(self) -> List[GraphState]:
        return self._shards

    @shards.setter
    def shards(self, value) -> None:
        # same invalidation contract as the ``state`` setter, for the
        # sharded snapshot bookkeeping (fused cache + per-shard delta bases)
        self._shards = list(value)
        self._csr = None
        self._delta_base = None
        self._shard_csr_bases = None
        self._delta_batches = []

    # -- batched API ------------------------------------------------------
    def apply(self, ops, us, vs=None) -> np.ndarray:
        """Apply a batch; returns bool[n] success per op (phase order = batch
        order).

        Batches are padded to power-of-two buckets with NOP lanes: the jitted
        engines specialize on batch size, and a serving workload publishes a
        different op count every step — unbucketed, that is a recompile per
        step (measured 1.09 s/step vs ~ms after bucketing)."""
        n = len(ops)
        if n == 0:
            # nothing to resolve: skip the padded engine dispatch entirely
            return np.zeros(0, bool)
        # read-only batches (contains/NOP only) leave the abstract graph
        # unchanged, so the cached traversal snapshot stays valid — keep it
        # across the state swap below instead of forcing a CSR rebuild.
        ops0 = np.asarray(ops, np.int32)
        us0 = np.asarray(us, np.int32)
        vs0 = np.zeros_like(us0) if vs is None else np.asarray(vs, np.int32)
        if self.n_shards > 1:
            return self._apply_sharded(ops0, us0, vs0)
        mutating = bool(np.isin(ops0, _MUTATING_OPS).any())
        saved_csr = None if mutating else self._csr
        # the pending-delta queue (base snapshot + unpadded batches since the
        # last query) survives the state swap below: read-only batches carry
        # it unchanged, mutating batches append to it so the next query folds
        # the whole queue in one apply_delta (lazy: an update-heavy stream
        # between queries pays nothing per batch, one fold per query epoch)
        delta_base, delta_batches = self._delta_base, self._delta_batches
        if mutating and self.csr_maintenance == "delta" and self._csr is not None:
            delta_base, delta_batches = self._csr, []
        bucket = _bucket_size(n)
        ops, us, vs = ops0, us0, vs0
        if bucket != n:
            pad = np.zeros(bucket - n, np.int32)  # OP_NOP = 0
            ops = np.concatenate([ops0, pad])
            us = np.concatenate([us0, pad])
            vs = np.concatenate([vs0, pad])
        batch = make_batch(ops, us, vs, phase_base=self._phase)
        self._phase += batch.size
        apply_fn = engine.apply_batch if self.mode == "waitfree" else fastpath.apply_batch_fpsp

        self._grow_csr = None
        for attempt in range(_MAX_GROW_ATTEMPTS):
            # keep the pre-state alive for transactional retry
            pre = self.state
            res = apply_fn(pre, batch)
            if bool(res.ok) and not self._needs_growth(res.state):
                grow_csr = self._grow_csr
                self.state = res.state
                if attempt > 0:
                    # growth rehashed the tables: every slot moved, so both
                    # the saved snapshot's and the queue's bases are void —
                    # the state setter already dropped them.  The rehash
                    # pre-compacted the grown state's snapshot, though
                    # (maintenance "snapshot-compact"): queue this batch
                    # against it so the next query pays one delta fold, not
                    # a full rebuild.
                    if (
                        mutating
                        and grow_csr is not None
                        and self.csr_maintenance == "delta"
                    ):
                        self._delta_base = grow_csr
                        self._delta_batches = [(ops0, us0, vs0)]
                    return np.asarray(res.success)[:n]
                if not mutating:
                    # abstractly identical pre/post state: the saved snapshot
                    # (own references to the old tables) and any pending
                    # queue stay exactly as valid as before the batch
                    self._csr = saved_csr
                    self._delta_base = delta_base
                    self._delta_batches = delta_batches
                elif delta_base is not None and self.csr_maintenance == "delta":
                    # queue the batch against the remembered base snapshot;
                    # traversal_csr() folds the queue on the next query.  A
                    # queue past the fold's own fallback threshold would
                    # rebuild anyway — drop it and stop accumulating.
                    delta_batches = delta_batches + [(ops0, us0, vs0)]
                    if sum(b[0].size for b in delta_batches) > delta_base.e_capacity // 4:
                        delta_base, delta_batches = None, []
                    self._delta_base = delta_base
                    self._delta_batches = delta_batches
                return np.asarray(res.success)[:n]
            # discard post-state; grow from pre-state; retry the same batch
            self.state = self._grow(pre)
        raise RuntimeError("graph growth did not converge")

    def _needs_growth(self, state: GraphState) -> bool:
        v, e, v_used, e_used = _live_counts(state)
        return bool(v_used > GROW_LOAD_FACTOR * state.v_capacity) or bool(
            e_used > GROW_LOAD_FACTOR * state.e_capacity
        )

    def _grow(self, state: GraphState) -> GraphState:
        v, e, v_used, e_used = _live_counts(state)
        new_vcap = state.v_capacity
        new_ecap = state.e_capacity
        # grow whichever table is crowded (or both); compaction alone can be
        # enough when tombstones dominate, but doubling keeps it simple and
        # amortized-O(1).
        if int(v_used) > GROW_LOAD_FACTOR * state.v_capacity / 2:
            new_vcap *= 2
        if int(e_used) > GROW_LOAD_FACTOR * state.e_capacity / 2:
            new_ecap *= 2
        if new_vcap == state.v_capacity and new_ecap == state.e_capacity:
            new_vcap *= 2
            new_ecap *= 2
        impl = maintenance.resolve_impl(self.maintenance_impl)
        # snapshot-compact rides the device pass nearly free; on the host it
        # would be an eager build_csr per grow attempt — leave that lazy
        with_csr = impl != "host" and self.csr_maintenance == "delta"
        new_state, csr = _rehash_escalating(state, new_vcap, new_ecap, impl, with_csr)
        # stashed for apply(): becomes the delta base of the retried batch
        # (the state setter must not clear it — the grown state is installed
        # right after this returns)
        self._grow_csr = csr
        return new_state

    # -- hash-prefix sharded apply (see repro.core.sharding) ----------------

    def _apply_sharded(self, ops0, us0, vs0) -> np.ndarray:
        """The n_shards > 1 twin of ``apply``: route the batch, run every
        shard's engine pass (full batch shape, non-owned edge mutations
        rewritten read-only — the replica invariant), gather per-lane
        results from the owner shards, and grow transactionally on any
        shard's overflow.  Linearization is unchanged: one phase window per
        batch, shared by every shard.

        The snapshot bookkeeping below deliberately mirrors ``apply``'s
        state machine step for step (saved snapshot on read-only batches,
        delta-queue append with a footprint floor, growth seeding on
        attempt > 0) — when editing either twin, port the change to the
        other; only the queue-entry layout differs (routed per-shard op
        arrays here, one op array there) plus the floor, which takes the
        *minimum* shard e-capacity since every shard must stay foldable."""
        n = ops0.shape[0]
        mutating = bool(np.isin(ops0, _MUTATING_OPS).any())
        saved_csr = None if mutating else self._csr
        delta_bases, delta_batches = self._shard_csr_bases, self._delta_batches
        if mutating and self.csr_maintenance == "delta" and self._csr is not None:
            delta_bases, delta_batches = self._shard_csr_bases, []
        shard_ops, owner = sharding.route_ops(ops0, us0, vs0, self.n_shards)
        bucket = _bucket_size(n)
        pad = np.zeros(bucket - n, np.int32)
        us_p = np.concatenate([us0, pad])
        vs_p = np.concatenate([vs0, pad])
        batches = [
            make_batch(np.concatenate([so, pad]), us_p, vs_p, phase_base=self._phase)
            for so in shard_ops
        ]
        self._phase += bucket
        apply_fn = engine.apply_batch if self.mode == "waitfree" else fastpath.apply_batch_fpsp

        self._grow_shard_csrs = None
        for attempt in range(_MAX_GROW_ATTEMPTS):
            pre = self._shards  # kept alive for transactional retry
            results = [apply_fn(st, b) for st, b in zip(pre, batches)]
            states = [r.state for r in results]
            if all(bool(r.ok) for r in results) and not self._needs_growth_sharded(states):
                grow_csrs = self._grow_shard_csrs
                self.shards = states
                # vertex lanes: every replica agrees (shard 0 speaks); edge
                # lanes: the owner shard's result is the only real one
                success = np.stack([np.asarray(r.success)[:n] for r in results])
                out = success[owner, np.arange(n)]
                if attempt > 0:
                    # growth rehashed every shard, voiding all prior bases
                    # (the shards setter already dropped them) — but the
                    # rehash pre-compacted each grown shard's snapshot
                    # (maintenance "snapshot-compact"), so queue the retried
                    # batch against those: the next query pays one delta
                    # fold per shard instead of full rebuilds, exactly like
                    # the 1-shard path.
                    if (
                        mutating
                        and grow_csrs is not None
                        and self.csr_maintenance == "delta"
                        and all(c is not None for c in grow_csrs)
                    ):
                        self._shard_csr_bases = grow_csrs
                        self._delta_batches = [(shard_ops, us0, vs0)]
                    return out
                if not mutating:
                    self._csr = saved_csr
                    self._shard_csr_bases = delta_bases
                    self._delta_batches = delta_batches
                elif delta_bases is not None and self.csr_maintenance == "delta":
                    # queue the routed batch against the per-shard bases;
                    # traversal_csr() folds each shard's queue on next query
                    delta_batches = delta_batches + [(shard_ops, us0, vs0)]
                    floor = min(c.e_capacity for c in delta_bases) // 4
                    if sum(b[1].size for b in delta_batches) > floor:
                        delta_bases, delta_batches = None, []
                    self._shard_csr_bases = delta_bases
                    self._delta_batches = delta_batches
                return out
            self.shards = self._grow_shards(pre)
        raise RuntimeError("graph growth did not converge")

    def _needs_growth_sharded(self, states: List[GraphState]) -> bool:
        # one _live_counts dispatch per shard: the vertex check reads shard
        # 0's counts (the replicas agree byte-for-byte, shard 0 speaks)
        counts = [_live_counts(st) for st in states]
        if bool(counts[0][2] > GROW_LOAD_FACTOR * states[0].v_capacity):
            return True
        return any(
            bool(c[3] > GROW_LOAD_FACTOR * st.e_capacity)
            for c, st in zip(counts, states)
        )

    def _grow_shards(self, states: List[GraphState]) -> List[GraphState]:
        """Per-shard capacity policy: the vertex capacity is shared (one
        decision for all replicas, so they stay aligned), edge capacities
        double independently per crowded shard.  Every shard is rehashed in
        the same round even at unchanged capacity — vertex-tombstone
        compaction must happen in lockstep or the replicas would diverge."""
        v_used = int(_live_counts(states[0])[2])
        new_vcap = states[0].v_capacity
        if v_used > GROW_LOAD_FACTOR * new_vcap / 2:
            new_vcap *= 2
        new_ecaps = []
        for st in states:
            e_used = int(_live_counts(st)[3])
            crowded = e_used > GROW_LOAD_FACTOR * st.e_capacity / 2
            new_ecaps.append(2 * st.e_capacity if crowded else st.e_capacity)
        if new_vcap == states[0].v_capacity and all(
            ec == st.e_capacity for ec, st in zip(new_ecaps, states)
        ):
            new_vcap *= 2
            new_ecaps = [2 * ec for ec in new_ecaps]
        impl = maintenance.resolve_impl(self.maintenance_impl)
        # per-shard snapshot-compact rides the device rehash nearly free (one
        # argsort each); on the host it would be an eager build_csr per shard
        # per grow attempt — leave that lazy, same policy as 1-shard _grow
        with_csr = impl != "host" and self.csr_maintenance == "delta"
        for _ in range(_MAX_GROW_ATTEMPTS):
            outs = [
                maintenance.rehash(st, new_vcap, ec, impl=impl, with_csr=with_csr)
                for st, ec in zip(states, new_ecaps)
            ]
            oks = [bool(ok) for _, _, ok in outs]
            if all(oks):
                # stashed for _apply_sharded: becomes the per-shard delta
                # bases of the retried batch (the shards setter must not
                # clear it — the grown shards are installed right after)
                self._grow_shard_csrs = [c for _, c, _ in outs] if with_csr else None
                return sharding.place_shards([s for s, _, _ in outs], self._mesh)
            if not any(oks):
                # identical vertex replicas fail identically: when every
                # shard overflows, the vertex table is the likely culprit
                new_vcap *= 2
            new_ecaps = [2 * ec if not ok else ec for ec, ok in zip(new_ecaps, oks)]
        raise RuntimeError("rehash placement did not converge")

    # -- the paper's six-operation convenience API -------------------------
    def add_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_ADD_VERTEX], [u])[0])

    def remove_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_REMOVE_VERTEX], [u])[0])

    def contains_vertex(self, u: int) -> bool:
        return bool(self.apply([OP_CONTAINS_VERTEX], [u])[0])

    def add_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_ADD_EDGE], [u], [v])[0])

    def remove_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_REMOVE_EDGE], [u], [v])[0])

    def contains_edge(self, u: int, v: int) -> bool:
        return bool(self.apply([OP_CONTAINS_EDGE], [u], [v])[0])

    # -- traversal queries (batched wait-free reachability) -----------------
    #
    # All queries run against one cached TraversalCSR snapshot — a compacted,
    # consistent view of the post-batch state.  The snapshot is rebuilt lazily
    # after any ``apply`` (the linearization point of every query in between
    # is that batch boundary, like the related papers' wait-free snapshots).

    def traversal_csr(self) -> traversal.TraversalCSR:
        """The cached consistent snapshot all queries linearize against.

        With ``csr_maintenance="delta"``, update batches queued since the
        last query are folded into the previous snapshot in one
        :func:`repro.core.traversal.apply_delta` call (result-blind
        reconciliation re-probes the union of touched keys against the
        *current* state, so one fold over many batches is exact); otherwise
        the snapshot is recompacted from scratch.

        Sharded graphs (``n_shards > 1``) build/fold one CSR per shard —
        each fold sees only that shard's routed ops, so it stays O(shard
        batch) — and fuse them (:func:`repro.core.sharding.fuse_csrs`) into
        the one global snapshot every query linearizes against."""
        if self.n_shards > 1:
            if self._csr is None:
                if self._shard_csr_bases is not None and self._delta_batches:
                    us_cat = np.concatenate([b[1] for b in self._delta_batches])
                    vs_cat = np.concatenate([b[2] for b in self._delta_batches])
                    per_shard = [
                        traversal.apply_delta(
                            base,
                            st,
                            np.concatenate([b[0][s] for b in self._delta_batches]),
                            us_cat,
                            vs_cat,
                            impl=self.maintenance_impl,
                        )
                        for s, (base, st) in enumerate(
                            zip(self._shard_csr_bases, self._shards)
                        )
                    ]
                else:
                    per_shard = [traversal.build_csr(st) for st in self._shards]
                self._csr = sharding.fuse_csrs(per_shard)
                self._shard_csr_bases = per_shard
                self._delta_batches = []
            return self._csr
        if self._csr is None:
            if self._delta_base is not None and self._delta_batches:
                self._csr = traversal.apply_delta(
                    self._delta_base,
                    self.state,
                    np.concatenate([b[0] for b in self._delta_batches]),
                    np.concatenate([b[1] for b in self._delta_batches]),
                    np.concatenate([b[2] for b in self._delta_batches]),
                    impl=self.maintenance_impl,
                )
            else:
                self._csr = traversal.build_csr(self.state)
            self._delta_base = None
            self._delta_batches = []
        return self._csr

    @staticmethod
    def _pad_keys(keys: Sequence[int]) -> Tuple[np.ndarray, int]:
        """Pad a query key batch to a power-of-two bucket with EMPTY_KEY lanes
        (same recompile-avoidance trick as ``apply``'s NOP padding)."""
        arr = np.asarray(keys, np.int32)
        return traversal._pad_pow2(arr, int(EMPTY_KEY)), arr.shape[0]

    def reachable(self, us, vs) -> np.ndarray:
        """Batched directed reachability: bool[n], ``us[i] ↝ vs[i]``.

        False when either endpoint is absent; ``u ↝ u`` is True iff u exists
        (the empty path).  Scalars are accepted and return a plain bool."""
        scalar = np.isscalar(us)
        if scalar:
            us, vs = [us], [vs]
        if len(us) != len(vs):
            raise ValueError(f"reachable: {len(us)} sources vs {len(vs)} targets")
        pu, n = self._pad_keys(us)
        pv, _ = self._pad_keys(vs)
        out = np.asarray(
            traversal.reachable(self.traversal_csr(), pu, pv, impl=self.traversal_impl)
        )[:n]
        return bool(out[0]) if scalar else out

    def bfs(self, u: int) -> Dict[int, int]:
        """BFS level map from ``u``: {vertex_key: hop_distance}, ``u`` at 0.
        Empty when ``u`` is absent."""
        return self.bfs_batch([u])[0]

    def bfs_batch(self, sources: Sequence[int]) -> List[Dict[int, int]]:
        """Batched BFS: one level map per source, all against one snapshot."""
        pk, n = self._pad_keys(sources)
        csr = self.traversal_csr()
        levels = np.asarray(traversal.bfs_levels(csr, pk, impl=self.traversal_impl))[:n]
        v_key = np.asarray(csr.v_key)
        out = []
        for row in levels:
            hit = np.nonzero(row >= 0)[0]
            out.append({int(v_key[j]): int(row[j]) for j in hit})
        return out

    def khop(self, u: int, k: int) -> Set[int]:
        """Vertex keys within ≤k directed hops of ``u`` (including ``u``)."""
        pk, _ = self._pad_keys([u])
        csr = self.traversal_csr()
        mask = np.asarray(
            traversal.khop_mask(csr, pk, np.int32(k), impl=self.traversal_impl)
        )[0]
        v_key = np.asarray(csr.v_key)
        return {int(v_key[j]) for j in np.nonzero(mask)[0]}

    def get_path(self, u: int, v: int) -> Optional[List[int]]:
        """A shortest directed path ``u ↝ v`` as an explicit key list
        (``[u, ..., v]``; ``[u]`` when u == v), or ``None`` when unreachable
        or either endpoint is absent — the papers' ``GetPath``."""
        return self.get_path_batch([u], [v])[0]

    def get_path_batch(self, us, vs) -> List[Optional[List[int]]]:
        """Batched ``GetPath``: one shortest path (or None) per (u, v) pair,
        all answered against one snapshot.

        The device half (:func:`repro.core.traversal.path_probe`) records a
        parent slot per reached vertex as one extra scatter in the BFS level
        loop; the host walks the parent chain back from each target — at
        most one step per level, so reconstruction is O(path length)."""
        if len(us) != len(vs):
            raise ValueError(f"get_path_batch: {len(us)} sources vs {len(vs)} targets")
        pu, n = self._pad_keys(us)
        pv, _ = self._pad_keys(vs)
        csr = self.traversal_csr()
        levels, parents, vslot, vlive = (
            np.asarray(x)
            for x in traversal.path_probe(csr, pu, pv, impl=self.traversal_impl)
        )
        v_key = np.asarray(csr.v_key)
        out: List[Optional[List[int]]] = []
        for i in range(n):
            if not vlive[i] or levels[i, vslot[i]] < 0:
                out.append(None)
                continue
            chain = [int(vslot[i])]
            while levels[i, chain[-1]] > 0:
                chain.append(int(parents[i, chain[-1]]))
            out.append([int(v_key[s]) for s in reversed(chain)])
        return out

    # -- introspection ------------------------------------------------------
    def snapshot(self) -> Tuple[set, set]:
        """Abstract (V, E) — for oracle comparison in tests.

        Vectorized: one device pass computes the live-vertex and
        incarnation-valid-edge masks (shared with the traversal engine's CSR
        validity predicate); host work is O(live), not O(capacity).

        Sharded graphs union the per-shard edge sets (disjoint partitions)
        under the shard-0 vertex replica."""
        if self.n_shards > 1:
            verts = set()
            edges = set()
            for i, st in enumerate(self._shards):
                v_mask, e_mask = traversal.snapshot_live(st)
                if i == 0:  # vertex replicas agree: shard 0 speaks for all
                    verts = set(np.asarray(st.v_key)[np.asarray(v_mask)].tolist())
                e_mask = np.asarray(e_mask)
                eu = np.asarray(st.e_key_u)[e_mask].tolist()
                ev = np.asarray(st.e_key_v)[e_mask].tolist()
                edges |= set(zip(eu, ev))
            return verts, edges
        v_mask, e_mask = traversal.snapshot_live(self.state)
        v_mask = np.asarray(v_mask)
        e_mask = np.asarray(e_mask)
        verts = set(np.asarray(self.state.v_key)[v_mask].tolist())
        eu = np.asarray(self.state.e_key_u)[e_mask].tolist()
        ev = np.asarray(self.state.e_key_v)[e_mask].tolist()
        return verts, set(zip(eu, ev))
