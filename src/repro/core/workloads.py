"""Workload generators mirroring the paper's experimental setup (§5).

The paper: initial graph of 1000 vertices; each thread draws ops from one of
three distributions over (AddV, RemV, ConV, AddE, RemE, ConE):

  * lookup-intensive : (2.5, 2.5, 45, 2.5, 2.5, 45) %
  * balanced         : (12.5, 12.5, 25, 12.5, 12.5, 25) %
  * update-intensive : (22.5, 22.5, 5, 22.5, 22.5, 5) %

Here "threads" are batch lanes: a batch of n ops is the ODA published by n
logical submitters, resolved concurrently by the engines.
"""

from __future__ import annotations

import numpy as np

from .types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
)

MIXES = {
    "lookup": (0.025, 0.025, 0.45, 0.025, 0.025, 0.45),
    "balanced": (0.125, 0.125, 0.25, 0.125, 0.125, 0.25),
    "update": (0.225, 0.225, 0.05, 0.225, 0.225, 0.05),
    # traversal: edge-heavy build phase for reachability/BFS query workloads
    # (the workload family of arXiv 1809.00896 / 2310.02380) — AddE dominates
    # so the graph develops real path structure; RemV stays nonzero so
    # incarnation churn and stale edges are exercised, not just membership.
    "traversal": (0.10, 0.02, 0.08, 0.60, 0.05, 0.15),
    # query_heavy: the update-light regime where incremental CSR maintenance
    # (traversal.apply_delta) amortizes snap_ms — a trickle of mutations
    # (incl. RemV churn) under a flood of membership lookups.  Its
    # mutation-only restriction (renormalized) is what sample_update_batch
    # draws from, so the update side of the mix has a single definition.
    "query_heavy": (0.010, 0.003, 0.42, 0.045, 0.012, 0.51),
}

_OPS = np.array(
    [OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_CONTAINS_VERTEX,
     OP_ADD_EDGE, OP_REMOVE_EDGE, OP_CONTAINS_EDGE],
    dtype=np.int32,
)


def sample_batch(
    rng: np.random.Generator, n: int, mix: str = "balanced", key_space: int = 1000
):
    """Sample one op batch. Returns (ops, us, vs) numpy arrays."""
    probs = np.asarray(MIXES[mix])
    ops = _OPS[rng.choice(6, size=n, p=probs)]
    us = rng.integers(0, key_space, size=n).astype(np.int32)
    vs = rng.integers(0, key_space, size=n).astype(np.int32)
    return ops, us, vs


def sample_query_pairs(rng: np.random.Generator, n: int, key_space: int = 1000):
    """Sample (source, target) key pairs for batched reachability/GetPath
    queries."""
    us = rng.integers(0, key_space, size=n).astype(np.int32)
    vs = rng.integers(0, key_space, size=n).astype(np.int32)
    return us, vs


def sample_update_batch(rng: np.random.Generator, n: int, key_space: int = 1000):
    """Sample a small all-mutating batch — the mutation-only restriction of
    the ``query_heavy`` mix, renormalized (edge-add dominated, RemV nonzero
    so delta maintenance sees incarnation churn, not just inserts).  Sized
    so ``apply_delta`` folds it into a cached CSR for O(batch) instead of an
    O(capacity) rebuild."""
    probs = np.asarray(MIXES["query_heavy"], float)
    probs = np.where(np.isin(_OPS, (OP_CONTAINS_VERTEX, OP_CONTAINS_EDGE)), 0.0, probs)
    ops = _OPS[rng.choice(6, size=n, p=probs / probs.sum())]
    us = rng.integers(0, key_space, size=n).astype(np.int32)
    vs = rng.integers(0, key_space, size=n).astype(np.int32)
    return ops, us, vs


def skewed_update_batch(
    rng: np.random.Generator,
    n: int,
    key_space: int = 1000,
    zipf_a: float = 1.5,
    hot_key: int | None = None,
    hot_frac: float = 0.5,
):
    """Sample a mutation-only batch whose endpoints follow a Zipf law —
    the adversarial input for partitioned tables, where hash-prefix
    routing no longer guarantees balanced sub-batches.

    Endpoint keys are drawn as ``(zipf(a) - 1) % key_space`` so a handful
    of keys absorb most of the traffic.  If ``hot_key`` is given, a
    ``hot_frac`` fraction of the ``u`` endpoints is additionally pinned to
    that single key: every shard count must then survive one shard owning
    nearly the whole batch (the imbalance stress in test_sharding).  Op
    mix is the mutation-only restriction of ``query_heavy``, same as
    :func:`sample_update_batch`."""
    probs = np.asarray(MIXES["query_heavy"], float)
    probs = np.where(np.isin(_OPS, (OP_CONTAINS_VERTEX, OP_CONTAINS_EDGE)), 0.0, probs)
    ops = _OPS[rng.choice(6, size=n, p=probs / probs.sum())]
    us = ((rng.zipf(zipf_a, size=n) - 1) % key_space).astype(np.int32)
    vs = ((rng.zipf(zipf_a, size=n) - 1) % key_space).astype(np.int32)
    if hot_key is not None:
        pin = rng.random(n) < hot_frac
        us = np.where(pin, np.int32(hot_key), us)
    return ops, us, vs


def shard_balance(ops, us, vs, n_shards: int) -> np.ndarray:
    """Edge-op count per hash-prefix shard for one batch
    (:func:`repro.core.sharding.shard_of_edges` routing).

    The sanity metric behind the sharded benchmark/example rows: the mixes
    draw keys uniformly, so hash prefixes — and therefore shard loads —
    stay near-uniform; a skewed histogram here means a skewed key
    distribution, not a routing bug."""
    from .sharding import edge_shard_histogram

    return edge_shard_histogram(
        np.asarray(ops, np.int32), np.asarray(us, np.int32),
        np.asarray(vs, np.int32), n_shards,
    )


def initial_vertices(key_space: int = 1000):
    """The paper's initial graph: 1000 vertices (keys 0..999), no edges."""
    ops = np.full(key_space, OP_ADD_VERTEX, np.int32)
    us = np.arange(key_space, dtype=np.int32)
    vs = np.zeros(key_space, np.int32)
    return ops, us, vs
