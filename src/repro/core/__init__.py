"""repro.core — the paper's wait-free concurrent unbounded graph, in JAX.

Public API:
  * :class:`repro.core.graph.WaitFreeGraph` — unbounded graph, six ops,
    batched apply, growth, ``waitfree`` or ``fpsp`` engines.
  * :func:`repro.core.engine.apply_batch` — the wait-free combine pass.
  * :func:`repro.core.fastpath.apply_batch_fpsp` — fast-path-slow-path.
  * :mod:`repro.core.baselines` — coarse / serial / lock-free comparisons.
  * :mod:`repro.core.oracle` — sequential specification (ground truth).
  * :mod:`repro.core.traversal` — batched wait-free reachability/BFS/k-hop
    over compacted consistent snapshots (CSR), linearized at batch boundaries.
  * :mod:`repro.core.maintenance` — device-resident state maintenance:
    growth rehash (live-compact + snapshot-compact) and the CSR delta-merge,
    built on the :mod:`repro.kernels.compact` sort + prefix-sum primitives.
  * :mod:`repro.core.sharding` — hash-prefix partitioning of the tables
    across a device mesh (``WaitFreeGraph(n_shards=...)``): shard routing,
    per-shard engine passes, cross-shard CSR fusion.

The paper-to-code map — which paper concept lives in which module — is
``docs/ARCHITECTURE.md``.
"""

from . import maintenance, sharding
from .graph import WaitFreeGraph
from .oracle import SequentialGraph, run_sequential
from .traversal import (
    TraversalCSR,
    apply_delta,
    bfs_levels,
    bfs_parents,
    build_csr,
    khop_mask,
    path_probe,
    reachable,
)
from .types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_NOP,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    ApplyResult,
    GraphState,
    OpBatch,
    make_batch,
    make_state,
)

__all__ = [
    "WaitFreeGraph",
    "maintenance",
    "sharding",
    "SequentialGraph",
    "run_sequential",
    "TraversalCSR",
    "build_csr",
    "apply_delta",
    "bfs_levels",
    "bfs_parents",
    "path_probe",
    "reachable",
    "khop_mask",
    "GraphState",
    "OpBatch",
    "ApplyResult",
    "make_batch",
    "make_state",
    "OP_NOP",
    "OP_ADD_VERTEX",
    "OP_REMOVE_VERTEX",
    "OP_CONTAINS_VERTEX",
    "OP_ADD_EDGE",
    "OP_REMOVE_EDGE",
    "OP_CONTAINS_EDGE",
]
