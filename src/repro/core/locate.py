"""Bounded-probe locate and scatter-claim insertion for the hash tables.

``locate_*`` is the engine's analogue of the paper's ``WFLocateVertex`` /
``WFLocateEdge``: it returns, for every query key, either the slot holding the
key (live or tombstone — Harris "marked" nodes stay physically present until
compaction) or the first empty slot of its probe chain (the insert
candidate).  The probe chain is capped at MAX_PROBES — a locate that would
exceed the cap sets ``overflow`` and the host grows the table, which is what
keeps locate bounded (wait-free) instead of spinning.

``claim_slots`` implements deterministic parallel insertion: every pending key
scatters its priority into its candidate slot, winners are read back, losers
re-probe.  Rounds are bounded by MAX_INSERT_ROUNDS; exceeding the bound sets
``overflow`` (host grows and retries the whole batch transactionally).

A Pallas TPU kernel implementing the same probe loop with VMEM-tiled query
blocks lives in ``repro.kernels.hash_probe``; this module is the portable
reference used on CPU and in dry-runs.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .hashing import hash_edge, hash_vertex, probe_slot
from .types import EMPTY_KEY, MAX_INSERT_ROUNDS, MAX_PROBES


class LocateResult(NamedTuple):
    slot: jnp.ndarray      # i32[n] slot holding the key, or -1
    found: jnp.ndarray     # bool[n]
    insert_slot: jnp.ndarray  # i32[n] first empty slot on the chain, or -1
    overflow: jnp.ndarray  # bool[] any probe chain exhausted


def _locate(home: jnp.ndarray, match_at, capacity: int, active: jnp.ndarray) -> LocateResult:
    """Generic bounded probe. ``match_at(slot) -> (is_match, is_empty)``."""
    n = home.shape[0]
    slot0 = jnp.full((n,), -1, jnp.int32)

    def body(step, carry):
        found_slot, empty_slot = carry
        pending = (found_slot < 0) & (empty_slot < 0) & active
        s = probe_slot(home, jnp.int32(step), capacity)
        is_match, is_empty = match_at(s)
        found_slot = jnp.where(pending & is_match, s, found_slot)
        empty_slot = jnp.where(pending & is_empty & ~is_match, s, empty_slot)
        return (found_slot, empty_slot)

    found_slot, empty_slot = jax.lax.fori_loop(0, MAX_PROBES, body, (slot0, slot0))
    overflow = jnp.any(active & (found_slot < 0) & (empty_slot < 0))
    return LocateResult(found_slot, found_slot >= 0, empty_slot, overflow)


def locate_vertices(v_key: jnp.ndarray, keys: jnp.ndarray, active: jnp.ndarray) -> LocateResult:
    cap = v_key.shape[0]
    home = hash_vertex(keys, cap)

    def match_at(s):
        k = v_key[s]
        return (k == keys) & active, k == EMPTY_KEY

    return _locate(home, match_at, cap, active)


def locate_edges(
    e_key_u: jnp.ndarray,
    e_key_v: jnp.ndarray,
    us: jnp.ndarray,
    vs: jnp.ndarray,
    active: jnp.ndarray,
) -> LocateResult:
    cap = e_key_u.shape[0]
    home = hash_edge(us, vs, cap)

    def match_at(s):
        ku = e_key_u[s]
        kv = e_key_v[s]
        return ((ku == us) & (kv == vs)) & active, ku == EMPTY_KEY

    return _locate(home, match_at, cap, active)


def _claim_slots(
    key_cols: Tuple[jnp.ndarray, ...],
    query_cols: Tuple[jnp.ndarray, ...],
    home_of,
    want: jnp.ndarray,
) -> Tuple[Tuple[jnp.ndarray, ...], jnp.ndarray, jnp.ndarray]:
    """Insert unique new keys into empty slots, deterministically.

    key_cols:   the table's key column(s) — (v_key,) or (e_key_u, e_key_v).
    query_cols: matching per-query key column(s).
    home_of(query_cols, cap) -> i32[n] home slots.
    want: bool[n] — which query indices need insertion (their keys must be
          mutually distinct and absent from the table).

    Returns (updated key_cols, slots i32[n] (-1 where not wanted/failed),
    overflow flag, rounds i32[] — scatter-claim rounds consumed, the
    paper's helping-bound witness).  The claim is priority-ordered by query
    index, so the outcome is deterministic and identical on every device.
    """
    n = want.shape[0]
    cap = key_cols[0].shape[0]
    slots0 = jnp.full((n,), -1, jnp.int32)
    idx = jnp.arange(n, dtype=jnp.int32)
    int_max = jnp.iinfo(jnp.int32).max
    home = home_of(query_cols, cap)

    def cond(carry):
        _, _, pending, rounds = carry
        return jnp.any(pending) & (rounds < MAX_INSERT_ROUNDS)

    def body(carry):
        cols, slots, pending, rounds = carry
        first_col = cols[0]

        # bounded probe for the first empty slot on each pending chain
        def probe_body(step, empty_slot):
            s = probe_slot(home, jnp.int32(step), cap)
            is_empty = first_col[s] == EMPTY_KEY
            take = pending & (empty_slot < 0) & is_empty
            return jnp.where(take, s, empty_slot)

        cand = jax.lax.fori_loop(0, MAX_PROBES, probe_body, jnp.full((n,), -1, jnp.int32))
        has_cand = pending & (cand >= 0)
        safe_cand = jnp.where(has_cand, cand, 0)

        # scatter-claim: lowest query index wins each contended slot
        claim = jnp.full((cap,), int_max, jnp.int32)
        claim = claim.at[safe_cand].min(jnp.where(has_cand, idx, int_max))
        winner = has_cand & (claim[safe_cand] == idx)

        # winners write their key column(s); mode="drop" ignores losers (idx cap)
        wslot = jnp.where(winner, cand, cap)
        cols = tuple(
            col.at[wslot].set(qcol, mode="drop") for col, qcol in zip(cols, query_cols)
        )
        slots = jnp.where(winner, cand, slots)
        pending = pending & ~winner
        return (cols, slots, pending, rounds + 1)

    cols, slots, pending, rounds = jax.lax.while_loop(
        cond, body, (key_cols, slots0, want, jnp.int32(0))
    )
    overflow = jnp.any(pending)
    return cols, slots, overflow, rounds


def claim_vertex_slots(v_key, query_keys, want):
    cols, slots, overflow, rounds = _claim_slots(
        (v_key,), (query_keys,), lambda q, cap: hash_vertex(q[0], cap), want
    )
    return cols[0], slots, overflow, rounds


def claim_edge_slots(e_key_u, e_key_v, qu, qv, want):
    cols, slots, overflow, rounds = _claim_slots(
        (e_key_u, e_key_v), (qu, qv), lambda q, cap: hash_edge(q[0], q[1], cap), want
    )
    return cols[0], cols[1], slots, overflow, rounds
