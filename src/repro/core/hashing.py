"""Hash mixing and probe sequences for the open-addressing tables.

The paper walks sorted linked lists (``WFLocateVertex`` / ``WFLocateEdge``);
on a vector machine pointer chasing is hostile, so locate becomes a bounded
linear-probe over a power-of-two table.  The probe bound (MAX_PROBES) is what
keeps locate wait-free: a chain longer than the bound trips table growth
instead of spinning.

One 32-bit hash serves two consumers (see ``docs/ARCHITECTURE.md``): the
probe sequence uses its low bits (the *suffix*, ``& (capacity - 1)``) as the
home slot, and :mod:`repro.core.sharding` uses its top bits (the *prefix*)
as the shard id.  ``vertex_hash32`` / ``edge_hash32`` expose the full hash
so both consumers provably read the same value.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Finalizer from MurmurHash3 (public domain), on uint32 lanes."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _mix32_np(x: np.ndarray) -> np.ndarray:
    """numpy twin of :func:`_mix32` (uint32 wraparound), kept next to its
    source: the host rehash oracle (:mod:`repro.core.maintenance`) and the
    shard router (:mod:`repro.core.sharding`) must read *bit-identically*
    the hash the device probes with — one definition, not hand-copies."""
    x = x.astype(np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def vertex_hash32_np(key: np.ndarray) -> np.ndarray:
    """numpy twin of :func:`vertex_hash32`."""
    return _mix32_np(key)


def edge_hash32_np(us: np.ndarray, vs: np.ndarray) -> np.ndarray:
    """numpy twin of :func:`edge_hash32`."""
    return _mix32_np(us.astype(np.uint32) * np.uint32(0x9E3779B9) + _mix32_np(vs))


def vertex_hash32(key: jnp.ndarray) -> jnp.ndarray:
    """The full 32-bit vertex hash (uint32) the table suffix/shard prefix
    are both carved from."""
    return _mix32(key)


def edge_hash32(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """The full 32-bit edge hash (uint32); order-sensitive (directed)."""
    return _mix32(u.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + _mix32(v))


def hash_vertex(key: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Home slot for a vertex key in a power-of-two table."""
    return (vertex_hash32(key) & jnp.uint32(capacity - 1)).astype(jnp.int32)


def hash_edge(u: jnp.ndarray, v: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Home slot for an edge key pair (u, v); order-sensitive (directed)."""
    return (edge_hash32(u, v) & jnp.uint32(capacity - 1)).astype(jnp.int32)


def probe_slot(home: jnp.ndarray, step: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Triangular probing: home + step*(step+1)/2 mod capacity.

    For power-of-two capacities triangular probing visits every slot, like
    linear probing, but with better clustering behaviour.
    """
    off = (step * (step + 1)) // 2
    return (home + off) & (capacity - 1)
