"""Hash mixing and probe sequences for the open-addressing tables.

The paper walks sorted linked lists (``WFLocateVertex`` / ``WFLocateEdge``);
on a vector machine pointer chasing is hostile, so locate becomes a bounded
linear-probe over a power-of-two table.  The probe bound (MAX_PROBES) is what
keeps locate wait-free: a chain longer than the bound trips table growth
instead of spinning.
"""

from __future__ import annotations

import jax.numpy as jnp


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Finalizer from MurmurHash3 (public domain), on uint32 lanes."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def hash_vertex(key: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Home slot for a vertex key in a power-of-two table."""
    return (_mix32(key) & jnp.uint32(capacity - 1)).astype(jnp.int32)


def hash_edge(u: jnp.ndarray, v: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Home slot for an edge key pair (u, v); order-sensitive (directed)."""
    h = _mix32(u.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + _mix32(v))
    return (h & jnp.uint32(capacity - 1)).astype(jnp.int32)


def probe_slot(home: jnp.ndarray, step: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Triangular probing: home + step*(step+1)/2 mod capacity.

    For power-of-two capacities triangular probing visits every slot, like
    linear probing, but with better clustering behaviour.
    """
    off = (step * (step + 1)) // 2
    return (home + off) & (capacity - 1)
