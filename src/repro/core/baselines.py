"""Baseline engines matching the paper's comparison set (Fig. 4).

Paper baseline        -> dataflow analogue here
---------------------------------------------------------------------------
coarse lock [7]       -> ``apply_coarse``: host loop, one device round-trip
                         per op — global serialization.
HoH / lazy locks [6,7]-> ``apply_serial``: one ``lax.scan`` step per op
                         inside a single jit — device-side serialization with
                         per-op locate (the hand-over-hand walk); marked bits
                         give lazy-list logical deletion.
lock-free [4]         -> ``apply_lockfree``: optimistic vectorized rounds;
                         per conflict group the minimum-phase op "wins the
                         CAS", losers retry next round.  System-wide progress
                         every round, but no per-op bound (lock-freedom).
wait-free (paper)     -> ``repro.core.engine.apply_batch``.
fast-path-slow-path   -> ``repro.core.fastpath.apply_batch_fpsp``.

All five produce results exactly equal to the sequential oracle in phase
order; they differ in *how* (and in how many bounded steps) they get there —
which is precisely what the paper's Fig. 4 measures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .fastpath import _fast_apply
from .hashing import hash_edge, hash_vertex
from .types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    N_STATS,
    ApplyResult,
    GraphState,
    OpBatch,
)

_INT32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# lock-free: optimistic rounds, min-phase wins each conflict group
# ---------------------------------------------------------------------------

@jax.jit
def apply_lockfree(state: GraphState, batch: OpBatch) -> ApplyResult:
    op, u, v, phase = batch.op, batch.u, batch.v, batch.phase
    n = op.shape[0]
    nb = max(2 * n, 64)

    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    is_eop = (op == OP_ADD_EDGE) | (op == OP_REMOVE_EDGE) | (op == OP_CONTAINS_EDGE)
    real = is_vop | is_eop

    hv_u = hash_vertex(u, nb)
    hv_v = hash_vertex(v, nb)
    he = hash_edge(u, v, nb)

    def cond(carry):
        _, _, pending, _, rounds = carry
        return jnp.any(pending)

    def body(carry):
        st, success, pending, overflow, rounds = carry

        # min pending phase per vertex bucket (vertex ops + edge endpoints)
        vmin = jnp.full((nb,), _INT32_MAX, jnp.int32)
        pv = pending & is_vop
        pe = pending & is_eop
        vmin = vmin.at[jnp.where(pv, hv_u, 0)].min(jnp.where(pv, phase, _INT32_MAX))
        vmin = vmin.at[jnp.where(pe, hv_u, 0)].min(jnp.where(pe, phase, _INT32_MAX))
        vmin = vmin.at[jnp.where(pe, hv_v, 0)].min(jnp.where(pe, phase, _INT32_MAX))
        emin = jnp.full((nb,), _INT32_MAX, jnp.int32)
        emin = emin.at[jnp.where(pe, he, 0)].min(jnp.where(pe, phase, _INT32_MAX))

        # an op "wins its CAS" iff it is the min across every bucket it touches
        v_win = pv & (vmin[hv_u] == phase)
        e_win = pe & (vmin[hv_u] >= phase) & (vmin[hv_v] >= phase) & (emin[he] == phase)
        # (>= because the edge op's own phase is in those buckets; winning
        # requires no *lower* phase there)
        winner = v_win | e_win

        st, win_success, over, _, _ = _fast_apply(st, batch, winner)
        success = jnp.where(winner, win_success, success)
        pending = pending & ~winner
        return (st, success, pending, overflow | over, rounds + 1)

    init = (state, jnp.zeros((n,), bool), real, jnp.array(False), jnp.int32(0))
    st, success, pending, overflow, rounds = jax.lax.while_loop(cond, body, init)
    # stats[0] = optimistic retry rounds (the lock-freedom-not-wait-freedom
    # witness the contention tests pin); remaining slots unused
    stats = jnp.zeros((N_STATS,), jnp.int32).at[0].set(rounds)
    return ApplyResult(state=st, success=success, ok=~overflow, stats=stats)


# ---------------------------------------------------------------------------
# serialized: one op per lax.scan step (HoH / lazy locking analogue)
# ---------------------------------------------------------------------------

@jax.jit
def apply_serial(state: GraphState, batch: OpBatch) -> ApplyResult:
    def step(st, xs):
        op1, u1, v1, ph1 = xs
        one = OpBatch(op=op1[None], u=u1[None], v=v1[None], phase=ph1[None])
        st, succ, over, _, _ = _fast_apply(st, one, jnp.ones((1,), bool))
        return st, (succ[0], over)

    state, (success, overs) = jax.lax.scan(
        step, state, (batch.op, batch.u, batch.v, batch.phase)
    )
    stats = jnp.zeros((N_STATS,), jnp.int32)
    return ApplyResult(state=state, success=success, ok=~jnp.any(overs), stats=stats)


# ---------------------------------------------------------------------------
# coarse: host-side loop, one device call per op (global lock analogue)
# ---------------------------------------------------------------------------

@jax.jit
def _apply_one(state: GraphState, op, u, v, phase):
    one = OpBatch(op=op[None], u=u[None], v=v[None], phase=phase[None])
    st, succ, over, _, _ = _fast_apply(state, one, jnp.ones((1,), bool))
    return st, succ[0], over


def apply_coarse(state: GraphState, batch: OpBatch) -> ApplyResult:
    n = batch.size
    success = np.zeros((n,), bool)
    overflow = False
    order = np.argsort(np.asarray(batch.phase), kind="stable")
    for i in order:
        state, s, over = _apply_one(
            state, batch.op[i], batch.u[i], batch.v[i], batch.phase[i]
        )
        success[i] = bool(s)
        overflow = overflow or bool(over)
    return ApplyResult(
        state=state,
        success=jnp.asarray(success),
        ok=jnp.array(not overflow),
        stats=jnp.zeros((N_STATS,), jnp.int32),
    )


ENGINES = {
    "coarse": apply_coarse,
    "serial": apply_serial,
    "lockfree": apply_lockfree,
}
