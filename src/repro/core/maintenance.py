"""Device-side state maintenance: rehash, physical deletion, CSR delta-merge.

The paper keeps its graph *unbounded* by growing and compacting the
vertex/edge tables; its practicality rests on that maintenance never
stalling the mutation path.  This module is the device-resident analogue of
the physical-deletion/compaction discipline of arXiv 2310.02380's wait-free
snapshot graphs: three operations sharing one sort + prefix-sum core (the
:mod:`repro.kernels.compact` primitives).

1. **live-compact** (:func:`rehash`) — mask the live vertices and the
   incarnation-valid live edges, compact them in table-slot order
   (``masked_compact``), and bulk re-insert into the grown tables with the
   vectorized quadratic-probe placement kernel (``probe_place``).  This
   replaces the per-element Python loops the host rehash used to run.
   Placement is bounded by ``MAX_PROBES`` — the engines' own locate bound,
   so every placed key is locatable by construction; a placement that
   would exceed it reports ``ok=False`` and the caller grows further
   (exactly the transactional grow-and-retry the engines already use).

2. **snapshot-compact** (``rehash(..., with_csr=True)``) — the compaction
   already knows every surviving edge's endpoint slots in the *new* table
   (an old-slot → new-slot scatter), so the dense :class:`TraversalCSR`
   falls out of the same pass without re-probing anything: ``build_csr``
   after a growth event costs one argsort instead of a full bounded-probe
   relocate.  The result is bit-identical to ``build_csr`` on the new
   state.

3. **delta-merge** (:func:`delta_merge`) — the device half of
   :func:`repro.core.traversal.apply_delta`: drop the lanes invalidated by
   the batch (prefix-sum compaction of the survivors), sort the
   O(batch)-sized delta, and splice it into the surviving runs with a
   device-side ``searchsorted`` merge — no host round-trip, no O(valid
   edges) lexsort.  Bit-identical to a full rebuild by construction.

Impl selection (the ``maintenance_impl`` flag on ``WaitFreeGraph``):

* ``"host"`` — the numpy oracle (:func:`rehash_host`): vectorized claim
  rounds with the *identical* discipline, kept as the reference every
  device path must match bit-exactly, and as the fallback when a device
  path is unavailable.
* ``"device"`` — the :mod:`repro.kernels.compact` primitives (Pallas
  kernel on TPU, pure-jnp reference elsewhere; ``REPRO_COMPACT_IMPL``
  overrides).
* ``"device_interpret"`` — the Pallas kernels through the interpreter
  (CI parity on CPU).
* ``None`` — auto: ``"device"`` on TPU, ``"host"`` elsewhere (the same
  per-backend dispatch the kernel families use: XLA CPU lowers the
  scatter/sort core near-serially, so the host oracle wins there).

All impls produce bit-identical tables: placement is priority-ordered
claim rounds (lowest compaction index wins each contended slot), which is
deterministic and order-independent of how the rounds are vectorized —
see ``repro.kernels.compact.ref`` and ``docs/KERNELS.md`` (the shared
``kernel/ops/ref`` contract and the ``probe_place`` VMEM limit).

**Linearization point** (mirroring the paper's growth argument): *a rehash
linearizes at the batch boundary that triggered it — the caller discards
the overflowing post-state and re-applies the same batch against the grown
pre-state, so no operation ever observes a half-compacted table, and the
abstract graph before and after the rehash is identical* (physical deletion
only reclaims tombstones and incarnation-stale edges, which are already
outside the abstract state).  A ``delta_merge`` inherits the linearization
point of the CSR it folds into (:mod:`repro.core.traversal`).  Under
hash-prefix sharding (:mod:`repro.core.sharding`) each shard rehashes its
own partitioned tables with this exact code — placement is per-shard by
construction — except that edge validity is judged against the *global*
sorted endpoint index (the ``endpoints`` override on :func:`rehash`):
an edge's endpoints generally live on other shards, and a shard-local
check would wrongly discard every cross-shard edge.

Telemetry: when an obs registry is active (``repro.obs``), host placement
records a ``maintenance.claim_rounds`` histogram and :func:`rehash` wraps
itself in a ``maintenance.rehash.<impl>`` span — catalogued in
``docs/OBSERVABILITY.md``.  None of it alters the computed tables.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.compact import masked_compact, probe_place
from repro.kernels.compact.ops import _resolve as _resolve_compact_impl

# ambient telemetry (no-op unless a registry is active — see repro.obs;
# metrics imports nothing from repro.core, so this is cycle-free)
from ..obs import metrics as obsm
from .hashing import edge_hash32_np, hash_edge, hash_vertex, vertex_hash32_np
from .traversal import TraversalCSR, _delta_probe_parts, _edge_validity, build_csr
from .types import ABSENT_INC, EMPTY_KEY, MAX_PROBES, GraphState

MAINTENANCE_IMPLS = (None, "host", "device", "device_interpret")

# Composite (src, lane) merge keys must fit int32 (x64 stays disabled);
# beyond this the delta fold falls back to the host splice.
_MERGE_KEY_LIMIT = 2**31


def resolve_impl(impl: Optional[str]) -> str:
    """``None`` -> the backend's best impl (device on TPU, host elsewhere)."""
    assert impl in MAINTENANCE_IMPLS, impl
    if impl is None:
        return "device" if jax.default_backend() == "tpu" else "host"
    return impl


def _primitive_impl(impl: Optional[str]) -> str:
    """Map a maintenance-level impl to a kernels.compact impl string
    (resolved eagerly so it is a static jit argument)."""
    if impl == "device_interpret":
        return "kernel_interpret"
    return _resolve_compact_impl(None)


# ---------------------------------------------------------------------------
# host oracle: vectorized numpy claim rounds (the bit-identity reference)
# ---------------------------------------------------------------------------


def _vhome_np(keys: np.ndarray, capacity: int) -> np.ndarray:
    # home slots from the shared numpy hash twins (repro.core.hashing keeps
    # them next to the jnp source so the oracle can never drift)
    return (vertex_hash32_np(keys) & np.uint32(capacity - 1)).astype(np.int32)


def _ehome_np(us: np.ndarray, vs: np.ndarray, capacity: int) -> np.ndarray:
    return (edge_hash32_np(us, vs) & np.uint32(capacity - 1)).astype(np.int32)


def _probe_place_host(
    home: np.ndarray, capacity: int, max_probes: int
) -> Tuple[np.ndarray, bool]:
    """numpy mirror of ``repro.kernels.compact.probe_place_rounds`` for
    all-active lanes: identical rounds, claims, and tie-breaks, so the
    resulting placement is bit-identical to the device paths."""
    m = home.shape[0]
    occ = np.zeros(capacity, bool)
    slots = np.full(m, -1, np.int32)
    pending = np.ones(m, bool)
    idx = np.arange(m, dtype=np.int64)
    int_max = np.iinfo(np.int32).max
    rounds = 0
    while pending.any() and rounds < m:
        cand = np.full(m, -1, np.int32)
        for step in range(max_probes):
            s = (home + step * (step + 1) // 2) & (capacity - 1)
            take = pending & (cand < 0) & ~occ[s]
            cand[take] = s[take]
        has = pending & (cand >= 0)
        if not has.any():
            break  # no candidate anywhere: overflow
        claim = np.full(capacity, int_max, np.int64)
        np.minimum.at(claim, cand[has], idx[has])
        safe = np.where(has, cand, 0)
        winner = has & (claim[safe] == idx)
        occ[cand[winner]] = True
        slots[winner] = cand[winner]
        pending &= ~winner
        rounds += 1
    # rounds-per-placement is the helping bound's maintenance-side twin;
    # the loop counts them regardless — obs just files the number
    obsm.hist("maintenance.claim_rounds", rounds)
    return slots, bool(pending.any())


def rehash_host(
    state: GraphState,
    new_vcap: int,
    new_ecap: int,
    endpoints: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[GraphState, bool]:
    """Grow + compact on the host (numpy): keep live vertices (with
    incarnations) and incarnation-valid live edges only — Harris physical
    deletion, batched.  This is the oracle the device paths are tested
    bit-identical against; it is vectorized numpy throughout (the
    per-element Python loops it replaced live only in git history).

    ``endpoints``, when given, is the sorted global ``(keys, incs)`` live
    vertex index edge validity is judged against instead of this state's
    own vertex table — the partitioned-shard case, where an edge's
    endpoints generally live on *other* shards
    (:func:`repro.core.sharding.gather_live_vertices`)."""
    v_key = np.asarray(state.v_key)
    v_live = np.asarray(state.v_live)
    v_inc = np.asarray(state.v_inc)

    v_sel = np.flatnonzero(v_live)  # compaction order = table-slot order
    keys = v_key[v_sel]
    incs = v_inc[v_sel]
    vslots, v_over = _probe_place_host(_vhome_np(keys, new_vcap), new_vcap, MAX_PROBES)

    n_vkey = np.full(new_vcap, EMPTY_KEY, np.int32)
    n_vlive = np.zeros(new_vcap, bool)
    n_vinc = np.full(new_vcap, ABSENT_INC, np.int32)
    placed = vslots >= 0
    n_vkey[vslots[placed]] = keys[placed]
    n_vinc[vslots[placed]] = incs[placed]
    n_vlive[vslots[placed]] = True

    # edge validity: live lane AND both endpoints live at the bound
    # incarnation (the Fig. 3 hazard mask, numpy edition: binary search over
    # the sorted live-key column replaces the device's bounded-probe locate)
    e_ku = np.asarray(state.e_key_u)
    e_kv = np.asarray(state.e_key_v)
    e_live = np.asarray(state.e_live)
    e_bu = np.asarray(state.e_inc_u)
    e_bv = np.asarray(state.e_inc_v)

    if endpoints is None:
        order = np.argsort(keys, kind="stable")
        sk, si = keys[order], incs[order]
    else:
        sk, si = endpoints

    def inc_now(qs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        if sk.size == 0:
            return np.zeros(qs.shape, bool), np.zeros(qs.shape, np.int32)
        pos = np.searchsorted(sk, qs)
        pos_c = np.minimum(pos, sk.size - 1)
        found = (pos < sk.size) & (sk[pos_c] == qs)
        return found, si[pos_c]

    e_sel = np.flatnonzero(e_live)
    fu, iu = inc_now(e_ku[e_sel])
    fv, iv = inc_now(e_kv[e_sel])
    valid = fu & fv & (iu == e_bu[e_sel]) & (iv == e_bv[e_sel])
    e_sel = e_sel[valid]  # stale edges: physical deletion

    eslots, e_over = _probe_place_host(
        _ehome_np(e_ku[e_sel], e_kv[e_sel], new_ecap), new_ecap, MAX_PROBES
    )
    n_eku = np.full(new_ecap, EMPTY_KEY, np.int32)
    n_ekv = np.full(new_ecap, EMPTY_KEY, np.int32)
    n_elive = np.zeros(new_ecap, bool)
    n_ebu = np.full(new_ecap, ABSENT_INC, np.int32)
    n_ebv = np.full(new_ecap, ABSENT_INC, np.int32)
    eplaced = eslots >= 0
    n_eku[eslots[eplaced]] = e_ku[e_sel][eplaced]
    n_ekv[eslots[eplaced]] = e_kv[e_sel][eplaced]
    n_ebu[eslots[eplaced]] = e_bu[e_sel][eplaced]
    n_ebv[eslots[eplaced]] = e_bv[e_sel][eplaced]
    n_elive[eslots[eplaced]] = True

    new_state = GraphState(
        v_key=jnp.asarray(n_vkey),
        v_live=jnp.asarray(n_vlive),
        v_inc=jnp.asarray(n_vinc),
        e_key_u=jnp.asarray(n_eku),
        e_key_v=jnp.asarray(n_ekv),
        e_live=jnp.asarray(n_elive),
        e_inc_u=jnp.asarray(n_ebu),
        e_inc_v=jnp.asarray(n_ebv),
    )
    return new_state, not (v_over or e_over)


# ---------------------------------------------------------------------------
# device live-compact (+ snapshot-compact)
# ---------------------------------------------------------------------------


def _edge_validity_sorted(
    state: GraphState, sorted_key: jnp.ndarray, sorted_inc: jnp.ndarray
) -> jnp.ndarray:
    """Edge validity against an external sorted (key, inc) endpoint index —
    the device twin of ``rehash_host``'s ``inc_now`` closure under an
    ``endpoints`` override (partitioned shards: endpoints live elsewhere).
    Padding lanes carry INT32_MAX keys / ABSENT_INC incs and can never
    validate a real edge."""
    n = sorted_key.shape[0]
    if n == 0:
        return jnp.zeros(state.e_capacity, bool)

    def look(q):
        pos = jnp.searchsorted(sorted_key, q)
        pc = jnp.minimum(pos, n - 1)
        found = (pos < n) & (sorted_key[pc] == q)
        return found, sorted_inc[pc]

    fu, iu = look(state.e_key_u)
    fv, iv = look(state.e_key_v)
    return (
        state.e_live
        & fu
        & fv
        & (iu == state.e_inc_u)
        & (iv == state.e_inc_v)
    )


@functools.partial(
    jax.jit, static_argnames=("new_vcap", "new_ecap", "prim", "with_csr")
)
def _rehash_device(
    state: GraphState,
    new_vcap: int,
    new_ecap: int,
    prim: str,
    with_csr: bool,
    endpoints=None,
):
    cv_old = state.v_capacity
    ce_old = state.e_capacity
    i32 = jnp.int32

    # --- vertices: compact live lanes in slot order, place into new table
    vvals = jnp.stack(
        [state.v_key, state.v_inc, jnp.arange(cv_old, dtype=i32)]
    )
    vcomp, n_v = masked_compact(vvals, state.v_live, fill=-1, impl=prim)
    keys_c, inc_c, oldslot_c = vcomp
    v_active = jnp.arange(cv_old, dtype=i32) < n_v
    vhome = jnp.where(v_active, hash_vertex(keys_c, new_vcap), 0)
    vslots, v_over = probe_place(
        vhome, v_active, capacity=new_vcap, max_probes=MAX_PROBES, impl=prim
    )
    wv = jnp.where(v_active & (vslots >= 0), vslots, new_vcap)
    n_vkey = jnp.full(new_vcap, EMPTY_KEY, i32).at[wv].set(keys_c, mode="drop")
    n_vinc = jnp.full(new_vcap, ABSENT_INC, i32).at[wv].set(inc_c, mode="drop")
    n_vlive = jnp.zeros(new_vcap, bool).at[wv].set(True, mode="drop")

    # old slot -> new slot (consumed by the snapshot-compact below)
    old2new = jnp.full(cv_old + 1, new_vcap, i32)
    old2new = old2new.at[jnp.where(v_active, oldslot_c, cv_old + 1)].set(
        vslots, mode="drop"
    )

    # --- edges: mask stale bindings, compact, place
    if endpoints is None:
        su_old, sv_old, valid = _edge_validity(state)
    else:
        # partitioned shard: endpoints judged against the global sorted
        # index (old endpoint slots are meaningless here — snapshot-compact
        # requires local endpoints, enforced by rehash())
        valid = _edge_validity_sorted(state, *endpoints)
        su_old = sv_old = jnp.zeros(ce_old, i32)
    evals = jnp.stack(
        [
            state.e_key_u,
            state.e_key_v,
            state.e_inc_u,
            state.e_inc_v,
            su_old.astype(i32),
            sv_old.astype(i32),
        ]
    )
    ecomp, n_e = masked_compact(evals, valid, fill=-1, impl=prim)
    eu_c, ev_c, ebu_c, ebv_c, esu_c, esv_c = ecomp
    e_active = jnp.arange(ce_old, dtype=i32) < n_e
    ehome = jnp.where(e_active, hash_edge(eu_c, ev_c, new_ecap), 0)
    eslots, e_over = probe_place(
        ehome, e_active, capacity=new_ecap, max_probes=MAX_PROBES, impl=prim
    )
    we = jnp.where(e_active & (eslots >= 0), eslots, new_ecap)
    n_eku = jnp.full(new_ecap, EMPTY_KEY, i32).at[we].set(eu_c, mode="drop")
    n_ekv = jnp.full(new_ecap, EMPTY_KEY, i32).at[we].set(ev_c, mode="drop")
    n_ebu = jnp.full(new_ecap, ABSENT_INC, i32).at[we].set(ebu_c, mode="drop")
    n_ebv = jnp.full(new_ecap, ABSENT_INC, i32).at[we].set(ebv_c, mode="drop")
    n_elive = jnp.zeros(new_ecap, bool).at[we].set(True, mode="drop")

    new_state = GraphState(
        v_key=n_vkey,
        v_live=n_vlive,
        v_inc=n_vinc,
        e_key_u=n_eku,
        e_key_v=n_ekv,
        e_live=n_elive,
        e_inc_u=n_ebu,
        e_inc_v=n_ebv,
    )
    ok = ~(v_over | e_over)
    if not with_csr:
        return new_state, None, ok

    # --- snapshot-compact: the CSR of the new state without re-probing.
    # Every compacted edge knows its endpoints' old slots; old2new turns
    # them into new slots, so only build_csr's argsort remains.
    safe_su = jnp.where(e_active, esu_c, cv_old)
    safe_sv = jnp.where(e_active, esv_c, cv_old)
    src_lane = jnp.full(new_ecap, new_vcap, i32).at[we].set(
        old2new[safe_su], mode="drop"
    )
    dst_lane = jnp.full(new_ecap, new_vcap, i32).at[we].set(
        old2new[safe_sv], mode="drop"
    )
    csr_order = jnp.argsort(src_lane, stable=True).astype(i32)
    src_sorted = src_lane[csr_order]
    dst_sorted = dst_lane[csr_order]
    rows = jnp.arange(new_vcap, dtype=i32)
    csr = TraversalCSR(
        v_key=n_vkey,
        v_live=n_vlive,
        v_inc=n_vinc,
        n_live=n_v,
        src=src_sorted,
        dst=dst_sorted,
        lane=csr_order,
        row_start=jnp.searchsorted(src_sorted, rows, side="left").astype(i32),
        row_end=jnp.searchsorted(src_sorted, rows, side="right").astype(i32),
        n_edges=n_e,
    )
    return new_state, csr, ok


def rehash(
    state: GraphState,
    new_vcap: int,
    new_ecap: int,
    *,
    impl: Optional[str] = None,
    with_csr: bool = False,
    endpoints: Optional[Tuple[np.ndarray, np.ndarray]] = None,
) -> Tuple[GraphState, Optional[TraversalCSR], bool]:
    """Grow + compact into fresh ``(new_vcap, new_ecap)`` tables.

    Returns ``(new_state, csr, ok)``.  ``csr`` is the ready-made
    :class:`TraversalCSR` of the new state when ``with_csr`` (bit-identical
    to ``build_csr(new_state)``), else ``None``.  ``ok=False`` means a
    probe chain would have exceeded ``MAX_PROBES`` — the new state must be
    discarded and the caller should grow further, exactly like a failed
    engine pass.  All impls are bit-identical; see the module docstring.

    ``endpoints`` — sorted global ``(keys, incs)`` numpy arrays — replaces
    the state's own vertex table as the edge-validity reference: the
    partitioned-shard case, where an edge's endpoints generally live on
    other shards.  Incompatible with ``with_csr`` (the snapshot-compact's
    slot remap is local by construction; the fused snapshot is rebuilt via
    :func:`repro.core.sharding.fuse_partitioned` instead).
    """
    impl = resolve_impl(impl)
    assert endpoints is None or not with_csr, (
        "snapshot-compact requires local endpoints"
    )
    with obsm.span(f"maintenance.rehash.{impl}"):
        obsm.counter("maintenance.rehash")
        if impl == "host":
            new_state, ok = rehash_host(state, new_vcap, new_ecap, endpoints)
            csr = build_csr(new_state) if (with_csr and ok) else None
            return new_state, csr, ok
        prim = _primitive_impl(impl)
        ep = None
        if endpoints is not None:
            # pow2-pad the sorted index so the jitted rehash compiles once per
            # bucket (INT32_MAX keys sort to the tail and never match)
            sk, si = endpoints
            m = sk.shape[0]
            bucket = max(16, 1 << max(m - 1, 1).bit_length())
            skp = np.full(bucket, np.iinfo(np.int32).max, np.int32)
            sip = np.full(bucket, ABSENT_INC, np.int32)
            skp[:m] = sk
            sip[:m] = si
            ep = (jnp.asarray(skp), jnp.asarray(sip))
        new_state, csr, ok = _rehash_device(
            state, new_vcap, new_ecap, prim, with_csr, ep
        )
        return new_state, csr, bool(ok)


# ---------------------------------------------------------------------------
# device delta-merge (the searchsorted splice of apply_delta)
# ---------------------------------------------------------------------------


def merge_keys_fit(cv: int, ce: int) -> bool:
    """Whether composite (src, lane) merge keys fit int32 for these
    capacities (the device merge's applicability guard)."""
    return cv * ce < _MERGE_KEY_LIMIT


@functools.partial(jax.jit, static_argnames=("nv", "ne", "prim"))
def _delta_merge_device(
    csr: TraversalCSR,
    state: GraphState,
    pack: jnp.ndarray,
    nv: int,
    ne: int,
    prim: str,
):
    i32 = jnp.int32
    cv = csr.v_capacity
    ce = csr.e_capacity
    big = jnp.iinfo(jnp.int32).max
    p = _delta_probe_parts(state, pack[:nv], pack[nv:nv + ne], pack[nv + ne:])

    # vertices whose (live, inc) changed invalidate every lane bound to them
    v_safe = jnp.where(p.v_found, p.v_slot, 0)
    changed = p.v_found & (
        (csr.v_live[v_safe] != p.v_live_now) | (csr.v_inc[v_safe] != p.v_inc_now)
    )
    hit = jnp.zeros(cv + 1, bool)
    hit = hit.at[jnp.where(changed, p.v_slot, cv + 1)].set(True, mode="drop")

    # every touched edge key is re-derived from the post state: drop its old
    # entry (if any) so the merge below is the single source
    ltouch = jnp.zeros(ce, bool)
    ltouch = ltouch.at[jnp.where(p.e_found, p.e_lane, ce)].set(True, mode="drop")

    in_prefix = jnp.arange(ce, dtype=i32) < csr.n_edges
    keep = in_prefix & ~(hit[csr.src] | hit[csr.dst]) & ~ltouch[csr.lane]
    svals = jnp.stack([csr.src, csr.dst, csr.lane])
    scomp, n_keep = masked_compact(svals, keep, fill=0, impl=prim)
    s_src, s_dst, s_lane = scomp
    s_active = jnp.arange(ce, dtype=i32) < n_keep
    s_key = jnp.where(s_active, s_src * ce + s_lane, big)

    # the O(batch) delta, sorted by the same (src, lane) order the rebuild's
    # stable argsort produces
    ins = p.e_found & p.e_valid
    d_key0 = jnp.where(ins, p.e_su * ce + p.e_lane, big)
    dorder = jnp.argsort(d_key0, stable=True)
    d_key = d_key0[dorder]
    d_src = p.e_su[dorder]
    d_dst = p.e_sv[dorder]
    d_lane = p.e_lane[dorder]
    d_ins = ins[dorder]
    n_ins = jnp.sum(ins).astype(i32)

    # searchsorted merge: keys are distinct (lanes are), so each side's final
    # position is its own rank plus the other side's count of smaller keys
    pos_s = jnp.arange(ce, dtype=i32) + jnp.searchsorted(d_key, s_key).astype(i32)
    pos_s = jnp.where(s_active, pos_s, ce)
    pos_d = (
        jnp.arange(d_key.shape[0], dtype=i32)
        + jnp.searchsorted(s_key, d_key).astype(i32)
    )
    pos_d = jnp.where(d_ins, pos_d, ce)

    out_src = jnp.full(ce, cv, i32).at[pos_s].set(s_src, mode="drop")
    out_src = out_src.at[pos_d].set(d_src, mode="drop")
    out_dst = jnp.full(ce, cv, i32).at[pos_s].set(s_dst, mode="drop")
    out_dst = out_dst.at[pos_d].set(d_dst, mode="drop")
    out_lane = jnp.zeros(ce, i32).at[pos_s].set(s_lane, mode="drop")
    out_lane = out_lane.at[pos_d].set(d_lane, mode="drop")

    # tail: the unused lanes in ascending order, exactly where the rebuild's
    # stable argsort leaves the invalid lanes
    n_valid = n_keep + n_ins
    lane_used = jnp.zeros(ce, bool)
    lane_used = lane_used.at[jnp.where(s_active, s_lane, ce)].set(True, mode="drop")
    lane_used = lane_used.at[jnp.where(d_ins, d_lane, ce)].set(True, mode="drop")
    lanes = jnp.arange(ce, dtype=i32)
    ucomp, n_unused = masked_compact(lanes[None, :], ~lane_used, fill=0, impl=prim)
    tail_pos = jnp.where(lanes < n_unused, n_valid + lanes, ce)
    out_lane = out_lane.at[tail_pos].set(ucomp[0], mode="drop")

    rows = jnp.arange(cv, dtype=i32)
    return TraversalCSR(
        v_key=state.v_key,
        v_live=state.v_live,
        v_inc=state.v_inc,
        n_live=p.n_live,
        src=out_src,
        dst=out_dst,
        lane=out_lane,
        row_start=jnp.searchsorted(out_src, rows, side="left").astype(i32),
        row_end=jnp.searchsorted(out_src, rows, side="right").astype(i32),
        n_edges=n_valid,
    )


def delta_merge(
    csr: TraversalCSR,
    state: GraphState,
    pack: np.ndarray,
    nv: int,
    ne: int,
    *,
    impl: Optional[str] = None,
) -> TraversalCSR:
    """Fold the (deduplicated, bucket-padded, packed ``vkeys | e_us | e_vs``)
    touched keys into ``csr`` entirely on device — the searchsorted splice of
    :func:`repro.core.traversal.apply_delta`, one host-to-device transfer and
    zero device-to-host ones.  Callers are responsible for the fallback
    guards (capacity change, delta footprint, :func:`merge_keys_fit`);
    bit-identity to ``build_csr(state)`` holds by construction."""
    return _delta_merge_device(csr, state, pack, nv, ne, _primitive_impl(impl))
