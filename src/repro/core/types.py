"""Core types for the wait-free concurrent graph engine.

The paper's shared-memory structures map onto static-shape JAX arrays:

* ``VNode{val, vnext, enext, marked}``  -> open-addressing vertex table with a
  ``live`` bit (inverse of the paper's ``marked``) and an ``inc`` incarnation
  counter (the dataflow analogue of the companion report's ENode->VNode
  pointer, used to detect stale edges after a vertex is removed and re-added).
* ``ENode{val, enext, marked}``         -> open-addressing edge table keyed by
  ``(u_key, v_key)`` carrying the incarnations of both endpoints at bind time.
* ``ODA`` (operation descriptor array)  -> a literal device array of
  ``(phase, op_type, u, v)`` descriptors (:class:`OpBatch`).
* ``maxPhase`` fetch-and-add            -> host-side monotone counter plus a
  per-batch ``iota`` (see :class:`repro.core.graph.WaitFreeGraph`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# --- Operation codes (the paper's OpType enum) -------------------------------
OP_NOP = 0
OP_ADD_VERTEX = 1
OP_REMOVE_VERTEX = 2
OP_CONTAINS_VERTEX = 3
OP_ADD_EDGE = 4
OP_REMOVE_EDGE = 5
OP_CONTAINS_EDGE = 6

OP_NAMES = {
    OP_NOP: "nop",
    OP_ADD_VERTEX: "add_vertex",
    OP_REMOVE_VERTEX: "remove_vertex",
    OP_CONTAINS_VERTEX: "contains_vertex",
    OP_ADD_EDGE: "add_edge",
    OP_REMOVE_EDGE: "remove_edge",
    OP_CONTAINS_EDGE: "contains_edge",
}

VERTEX_OPS = (OP_ADD_VERTEX, OP_REMOVE_VERTEX, OP_CONTAINS_VERTEX)
EDGE_OPS = (OP_ADD_EDGE, OP_REMOVE_EDGE, OP_CONTAINS_EDGE)

# Sentinel for an empty hash slot / absent incarnation.
EMPTY_KEY = np.int32(-1)
ABSENT_INC = np.int32(-1)

# Bounded probe chain: the wait-free locate bound.  If any probe chain would
# exceed this, the engine reports failure and the host grows the table --
# the amortized-O(1) analogue of the paper's unbounded malloc.
MAX_PROBES = 32
MAX_INSERT_ROUNDS = 16
GROW_LOAD_FACTOR = 0.5


# --- Engine stats vector ------------------------------------------------------
# Every ApplyResult carries an i32[N_STATS] vector of counters the jitted
# programs compute anyway (mask sums, claim-round counters).  The obs layer
# (:mod:`repro.obs`) reads them host-side after the pass — the vector is
# always produced, so enabling observability never changes a jitted program.
N_STATS = 8
STAT_CONFLICTED = 0     # FPSP: ops on the slow path (lockfree: claim rounds)
STAT_V_CONFLICTS = 1    # FPSP: vertex-lane conflict-mask hits
STAT_E_CONFLICTS = 2    # FPSP: edge-lane conflict-mask hits
STAT_INSERTED = 3       # new physical slots claimed this batch
STAT_EDGE_DUP = 4       # duplicate (u, v) edge lanes (shard-invariant)
STAT_VOPS = 5           # vertex-op lanes in the batch (non-NOP)
STAT_EOPS = 6           # edge-op lanes in the batch (non-NOP)
STAT_CLAIM_ROUNDS = 7   # scatter-claim rounds consumed (helping bound)


def is_pow2(n: int) -> bool:
    """Power-of-two check shared by table capacities and shard counts (both
    must be powers of two so hash prefixes/suffixes are plain bit fields)."""
    return n > 0 and (n & (n - 1)) == 0


class GraphState(NamedTuple):
    """Functional (pure-pytree) state of the concurrent graph.

    All arrays are device arrays; the struct is immutable and every engine
    pass returns a new one.  ``live=False`` with a retained key is exactly a
    Harris "marked" node: logically deleted, physically present until a
    rehash (compaction) reclaims it.

    Under hash-prefix sharding (:mod:`repro.core.sharding`) one
    ``GraphState`` holds one *shard*: its vertex table is a deterministic
    replica shared by every shard, its edge table the shard's partition of
    the edge key space.  Nothing in the struct changes — sharding is a
    routing layer over unmodified per-shard states.
    """

    # vertex table (capacity Cv)
    v_key: jnp.ndarray   # i32[Cv], EMPTY_KEY for empty slots
    v_live: jnp.ndarray  # bool[Cv]
    v_inc: jnp.ndarray   # i32[Cv], bumped on every dead->live transition

    # edge table (capacity Ce), keyed by (u_key, v_key)
    e_key_u: jnp.ndarray  # i32[Ce]
    e_key_v: jnp.ndarray  # i32[Ce]
    e_live: jnp.ndarray   # bool[Ce]
    e_inc_u: jnp.ndarray  # i32[Ce] endpoint incarnations at bind time
    e_inc_v: jnp.ndarray  # i32[Ce]

    @property
    def v_capacity(self) -> int:
        return self.v_key.shape[0]

    @property
    def e_capacity(self) -> int:
        return self.e_key_u.shape[0]


class OpBatch(NamedTuple):
    """A batch of operation descriptors — the device-array ODA.

    ``phase`` is the linearization order (unique int32 per op).  The engine
    resolves every op's success/failure exactly as if the batch had been
    applied sequentially in increasing phase order.
    """

    op: jnp.ndarray     # i32[n] in OP_*
    u: jnp.ndarray      # i32[n] vertex key / edge source key
    v: jnp.ndarray      # i32[n] edge destination key (ignored for vertex ops)
    phase: jnp.ndarray  # i32[n] unique linearization stamps

    @property
    def size(self) -> int:
        return self.op.shape[0]


class ApplyResult(NamedTuple):
    state: GraphState
    success: jnp.ndarray   # bool[n] per-op result, original batch order
    ok: jnp.ndarray        # bool[] False => table overflow, host must grow+retry
    stats: jnp.ndarray     # i32[N_STATS], indexed by the STAT_* constants


def make_state(v_capacity: int = 1024, e_capacity: int = 4096) -> GraphState:
    """Fresh empty graph with the given table capacities (powers of two)."""
    assert is_pow2(v_capacity), "v_capacity must be a power of two"
    assert is_pow2(e_capacity), "e_capacity must be a power of two"
    return GraphState(
        v_key=jnp.full((v_capacity,), EMPTY_KEY, dtype=jnp.int32),
        v_live=jnp.zeros((v_capacity,), dtype=bool),
        v_inc=jnp.full((v_capacity,), ABSENT_INC, dtype=jnp.int32),
        e_key_u=jnp.full((e_capacity,), EMPTY_KEY, dtype=jnp.int32),
        e_key_v=jnp.full((e_capacity,), EMPTY_KEY, dtype=jnp.int32),
        e_live=jnp.zeros((e_capacity,), dtype=bool),
        e_inc_u=jnp.full((e_capacity,), ABSENT_INC, dtype=jnp.int32),
        e_inc_v=jnp.full((e_capacity,), ABSENT_INC, dtype=jnp.int32),
    )


def make_batch(ops, us, vs=None, phase_base: int = 0) -> OpBatch:
    """Build an OpBatch from Python/numpy sequences; phases = base + iota."""
    op = jnp.asarray(np.asarray(ops, dtype=np.int32))
    u = jnp.asarray(np.asarray(us, dtype=np.int32))
    if vs is None:
        v = jnp.zeros_like(u)
    else:
        v = jnp.asarray(np.asarray(vs, dtype=np.int32))
    n = op.shape[0]
    phase = phase_base + jnp.arange(n, dtype=jnp.int32)
    return OpBatch(op=op, u=u, v=v, phase=phase)
