"""Hash-prefix sharding of the graph tables across a device mesh.

The paper scales by letting every thread make progress against one shared
structure; the dataflow analogue scales by *partitioning* that structure
across devices.  This module is the routing layer that turns ``S``
unmodified per-shard :class:`~repro.core.types.GraphState` instances into
one graph (the decomposition arXiv 1809.00896 uses to keep reachability
queries independent of mutators, with the snapshot discipline of arXiv
2310.02380 at the cross-shard boundary).  See ``docs/ARCHITECTURE.md`` for
the paper-to-code map.

**Partition rule.**  Both tables partition by the *prefix* of the same
32-bit hash whose *suffix* the probe sequence already uses as the home slot
(:mod:`repro.core.hashing`):

* an edge key ``(u, v)`` lives in shard ``edge_hash32(u, v) >> (32 - log2 S)``;
* a vertex key ``u`` lives in shard ``vertex_hash32(u) >> (32 - log2 S)``.

Prefix and suffix are disjoint bit fields for any per-shard capacity
≤ ``2**(32 - log2 S)``, so routing is independent of within-shard probing
and every shard runs the existing ``hash_probe`` locate, ``probe_place``
placement, and ``masked_compact`` rehash **unchanged** — no kernel knows
sharding exists.  Each shard stores O(N/S) vertices and O(M/S) edges; no
vertex is ever replicated (pinned by ``tests/test_sharding.py``'s
occupancy checks).

**Batch routing** (:func:`route_ops`).  Each lane of a batch has exactly
one *owner* shard — the vertex owner for vertex ops, the edge owner for
edge ops — and each shard receives only its owned lanes, compacted
(O(batch/S) sub-batches; lanes keep their global phase stamps, so the
linearization order is the batch order exactly as with one shard).

**Stabbing wave.**  Edge ops must observe endpoint liveness *at their own
phase* (the paper's Fig. 3 subtlety), and an edge's endpoints generally
live on *other* shards.  Between vertex settlement and edge placement the
host runs an explicit cross-shard exchange: every edge lane emits two
``(endpoint, phase)`` queries, queries are routed to the endpoint's owner
shard, the owner answers (live, inc)-at-phase from its own vertex
transitions (:func:`repro.core.engine.answer_stabs` — the same merged
scan the monolithic engine runs in-batch), and the gathered answers feed
the owner shard's edge wave.  Claim priorities and FPSP conflict
semantics are preserved on each sub-batch because the edge wave itself is
unchanged — only its endpoint inputs arrive over the wire.

**Fusion** (:func:`fuse_partitioned`).  Per-shard vertex tables have
disjoint key sets and private slot spaces, so a cross-shard traversal
snapshot needs one *canonical global vertex directory*: the union of live
``(key, inc)`` pairs placed into a fresh open-addressing table with the
deterministic priority-ordered claim rounds the rehash oracle uses
(priority = key order).  The directory depends only on the live vertex
set — not on the shard count or per-shard layout — so ``n_shards ∈ {1, 2,
4}`` produce snapshots over the identical key set and every query answer
matches.  Edge lanes from all shards are validated against the directory
(incarnation match — the Fig. 3 hazard mask) and sorted into one CSR.

**Linearization** (mirroring the related papers' snapshot theorems): *a
cross-shard traversal snapshot is the fusion of the S per-shard states
taken after all S shards installed their post-batch tables; each shard's
state linearizes at the same batch boundary and shards partition both key
spaces disjointly, so the fused CSR is a consistent cut of the whole graph
at that boundary.*

``WaitFreeGraph(n_shards=...)`` (:mod:`repro.core.graph`) owns the
host-side loop: route → vertex settle → stab → gather → edge claim →
compact, plus per-shard transactional growth (each shard rehashes its own
tables against the global endpoint directory).  ``n_shards=1`` bypasses
this module entirely and is bit-identical to the pre-sharding code path.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import edge_hash32_np, vertex_hash32_np
from .maintenance import _probe_place_host
from .traversal import TraversalCSR
from .types import (
    ABSENT_INC,
    EDGE_OPS,
    EMPTY_KEY,
    GROW_LOAD_FACTOR,
    MAX_PROBES,
    OP_NOP,
    VERTEX_OPS,
    GraphState,
    is_pow2,
    make_state,
)


def shard_of_edges(us: np.ndarray, vs: np.ndarray, n_shards: int) -> np.ndarray:
    """Owner shard per edge key: the top ``log2 n_shards`` bits (prefix) of
    the same 32-bit hash whose suffix is the probe home slot."""
    assert is_pow2(n_shards), "n_shards must be a power of two"
    us = np.asarray(us, np.int32)
    if n_shards == 1:
        return np.zeros(us.shape, np.int32)
    k = n_shards.bit_length() - 1
    return (edge_hash32_np(us, np.asarray(vs, np.int32)) >> np.uint32(32 - k)).astype(
        np.int32
    )


def shard_of_vertices(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Owner shard per vertex key: the top ``log2 n_shards`` bits (prefix)
    of ``vertex_hash32`` — the same prefix/suffix split as the edge rule,
    so per-shard vertex capacity ≤ ``2**(32 - log2 S)`` keeps routing and
    probing on disjoint bit fields."""
    assert is_pow2(n_shards), "n_shards must be a power of two"
    keys = np.asarray(keys, np.int32)
    if n_shards == 1:
        return np.zeros(keys.shape, np.int32)
    k = n_shards.bit_length() - 1
    return (vertex_hash32_np(keys) >> np.uint32(32 - k)).astype(np.int32)


def route_ops(
    ops: np.ndarray, us: np.ndarray, vs: np.ndarray, n_shards: int
) -> Tuple[List[np.ndarray], np.ndarray]:
    """Partition a batch's lanes by owner shard.

    Returns ``(shard_idx, owner)``: ``owner[i]`` is the shard that owns
    lane ``i`` (vertex owner for vertex ops, edge owner for edge ops, 0
    for NOPs), and ``shard_idx[s]`` is the ascending lane-index array of
    shard ``s``'s owned non-NOP lanes.  Each lane appears in exactly one
    shard's list — sub-batches are O(batch/S), and no silhouette is
    replicated (the stabbing wave carries the cross-shard information the
    old read-only rewrite used to smuggle in)."""
    ops = np.asarray(ops, np.int32)
    us = np.asarray(us, np.int32)
    vs = np.asarray(vs, np.int32)
    owner = np.zeros(ops.shape, np.int32)
    is_vop = np.isin(ops, VERTEX_OPS)
    is_eop = np.isin(ops, EDGE_OPS)
    owner[is_vop] = shard_of_vertices(us[is_vop], n_shards)
    owner[is_eop] = shard_of_edges(us[is_eop], vs[is_eop], n_shards)
    active = ops != OP_NOP
    shard_idx = [
        np.flatnonzero(active & (owner == s)).astype(np.int64)
        for s in range(n_shards)
    ]
    return shard_idx, owner


def make_shard_states(
    v_shard_capacity: int, e_shard_capacity: int, n_shards: int
) -> List[GraphState]:
    """Fresh empty shards: each carries a ``1/n_shards`` partition of both
    the vertex and the edge key space (O(N/S) + O(M/S) per shard)."""
    return [make_state(v_shard_capacity, e_shard_capacity) for _ in range(n_shards)]


# ---------------------------------------------------------------------------
# canonical global vertex directory + cross-shard snapshot fusion
# ---------------------------------------------------------------------------


class VertexDirectory(NamedTuple):
    """A canonical global vertex table over the union of per-shard live
    vertices — the slot space cross-shard snapshots traverse in.

    Placement is deterministic in the live key *set* alone (keys sorted
    ascending, priority-ordered claim rounds, capacity the smallest
    power of two respecting ``GROW_LOAD_FACTOR``), so any shard counts
    holding the same abstract graph build byte-identical directories.
    ``sorted_key``/``sorted_inc``/``sorted_slot`` expose the same content
    as a binary-searchable index (edge validation, snapshots, rehash)."""

    v_key: np.ndarray     # i32[C] — EMPTY_KEY where unused
    v_live: np.ndarray    # bool[C]
    v_inc: np.ndarray     # i32[C]
    n_live: int
    sorted_key: np.ndarray   # i32[n_live] — live keys, ascending
    sorted_inc: np.ndarray   # i32[n_live]
    sorted_slot: np.ndarray  # i32[n_live] — directory slot per sorted key


def gather_live_vertices(
    states: Sequence[GraphState],
) -> Tuple[np.ndarray, np.ndarray]:
    """The union of live ``(key, inc)`` pairs across shards, sorted by key
    (shards partition the key space, so keys are globally unique).  This is
    the endpoint index the sharded rehash and snapshot validate edges
    against."""
    keys = []
    incs = []
    for st in states:
        live = np.asarray(st.v_live)
        keys.append(np.asarray(st.v_key)[live])
        incs.append(np.asarray(st.v_inc)[live])
    k = np.concatenate(keys) if keys else np.zeros(0, np.int32)
    i = np.concatenate(incs) if incs else np.zeros(0, np.int32)
    order = np.argsort(k, kind="stable")
    return k[order].astype(np.int32), i[order].astype(np.int32)


def _directory_capacity(n_live: int) -> int:
    cap = 64
    while n_live > GROW_LOAD_FACTOR * cap:
        cap *= 2
    return cap


def build_vertex_directory(states: Sequence[GraphState]) -> VertexDirectory:
    """Place the global live vertex set into one canonical open-addressing
    table (same hash, same triangular probing, same ``MAX_PROBES`` bound as
    the engines' locate — so :func:`repro.core.locate.locate_vertices`
    works on the directory columns unchanged).  Capacity escalates ×2 on
    placement overflow, exactly like a rehash."""
    sorted_key, sorted_inc = gather_live_vertices(states)
    n_live = sorted_key.shape[0]
    cap = _directory_capacity(n_live)
    for _ in range(24):
        home = (vertex_hash32_np(sorted_key) & np.uint32(cap - 1)).astype(np.int32)
        slots, overflow = _probe_place_host(home, cap, MAX_PROBES)
        if not overflow:
            v_key = np.full(cap, EMPTY_KEY, np.int32)
            v_live = np.zeros(cap, bool)
            v_inc = np.full(cap, ABSENT_INC, np.int32)
            v_key[slots] = sorted_key
            v_inc[slots] = sorted_inc
            v_live[slots] = True
            return VertexDirectory(
                v_key=v_key,
                v_live=v_live,
                v_inc=v_inc,
                n_live=int(n_live),
                sorted_key=sorted_key,
                sorted_inc=sorted_inc,
                sorted_slot=slots.astype(np.int32),
            )
        cap *= 2
    raise RuntimeError("vertex directory placement did not converge")


def _lookup_sorted(
    sorted_key: np.ndarray, queries: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """(found, position) of each query key in the ascending key index."""
    if sorted_key.size == 0:
        return np.zeros(queries.shape, bool), np.zeros(queries.shape, np.int64)
    pos = np.searchsorted(sorted_key, queries)
    pos_c = np.minimum(pos, sorted_key.size - 1)
    found = (pos < sorted_key.size) & (sorted_key[pos_c] == queries)
    return found, pos_c


def fuse_partitioned(
    states: Sequence[GraphState], directory: Optional[VertexDirectory] = None
) -> TraversalCSR:
    """Fuse S partitioned shard states into one global
    :class:`~repro.core.traversal.TraversalCSR`.

    The vertex columns are the canonical directory's (see
    :class:`VertexDirectory` — identical for any shard count holding the
    same abstract graph); edge lanes are concatenated across shards
    (global lane = shard offset + local lane, the provenance order),
    validated against the directory (live lane, both endpoints present,
    incarnations match), and stably sorted by source slot exactly like
    ``build_csr``.  Every traversal query (``reachable`` / ``bfs_parents``
    / ``path_probe`` / ``khop_mask``) runs on the result unchanged."""
    if directory is None:
        directory = build_vertex_directory(states)
    d = directory

    e_ku = np.concatenate([np.asarray(st.e_key_u) for st in states])
    e_kv = np.concatenate([np.asarray(st.e_key_v) for st in states])
    e_live = np.concatenate([np.asarray(st.e_live) for st in states])
    e_bu = np.concatenate([np.asarray(st.e_inc_u) for st in states])
    e_bv = np.concatenate([np.asarray(st.e_inc_v) for st in states])
    ce = e_ku.shape[0]
    cv = d.v_key.shape[0]

    if d.n_live == 0:
        # no live vertices -> no valid edges; the index arrays are empty
        # and must not be fancy-indexed
        valid = np.zeros(ce, bool)
        src = np.full(ce, cv, np.int32)
        dst = np.full(ce, cv, np.int32)
    else:
        fu, pu = _lookup_sorted(d.sorted_key, e_ku)
        fv, pv = _lookup_sorted(d.sorted_key, e_kv)
        valid = (
            e_live
            & fu
            & fv
            & (d.sorted_inc[pu] == e_bu)
            & (d.sorted_inc[pv] == e_bv)
        )
        src = np.where(valid, d.sorted_slot[pu], cv).astype(np.int32)
        dst = np.where(valid, d.sorted_slot[pv], cv).astype(np.int32)
    lane = np.arange(ce, dtype=np.int32)

    order = np.argsort(src, kind="stable")
    src, dst, lane = src[order], dst[order], lane[order]
    rows = np.arange(cv, dtype=np.int32)
    i32 = jnp.int32
    return TraversalCSR(
        v_key=jnp.asarray(d.v_key),
        v_live=jnp.asarray(d.v_live),
        v_inc=jnp.asarray(d.v_inc),
        n_live=jnp.asarray(d.n_live, i32),
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        lane=jnp.asarray(lane),
        row_start=jnp.asarray(np.searchsorted(src, rows, side="left"), i32),
        row_end=jnp.asarray(np.searchsorted(src, rows, side="right"), i32),
        n_edges=jnp.asarray(int(valid.sum()), i32),
    )


# ---------------------------------------------------------------------------
# mesh placement
# ---------------------------------------------------------------------------


def host_local_mesh() -> jax.sharding.Mesh:
    """A 1-D ``jax.sharding.Mesh`` over every local device (named
    ``"shard"``).  On single-device CPU this is the degenerate mesh the
    answer-identity tests pin the multi-shard path against; on a TPU slice
    the same code round-robins shards across real devices."""
    devs = np.asarray(jax.devices())
    return jax.sharding.Mesh(devs.reshape(-1), ("shard",))


def place_shards(
    states: Sequence[GraphState], mesh: Optional[jax.sharding.Mesh] = None
) -> List[GraphState]:
    """Pin shard ``i`` to mesh device ``i % n_devices`` (round-robin).

    Placement never changes values — shard states are pure pytrees — so it
    is a no-op semantically and a locality hint physically."""
    mesh = host_local_mesh() if mesh is None else mesh
    devs = list(mesh.devices.flat)
    return [jax.device_put(s, devs[i % len(devs)]) for i, s in enumerate(states)]


def edge_shard_histogram(
    ops: np.ndarray, us: np.ndarray, vs: np.ndarray, n_shards: int
) -> np.ndarray:
    """Edge-op count per shard for one batch — the balance metric (uniform
    keys → near-uniform prefixes; see ``workloads.shard_balance``)."""
    ops = np.asarray(ops, np.int32)
    mask = np.isin(ops, EDGE_OPS)
    sid = shard_of_edges(np.asarray(us, np.int32)[mask], np.asarray(vs, np.int32)[mask], n_shards)
    return np.bincount(sid, minlength=n_shards)


def vertex_shard_histogram(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Vertex count per owner shard — the vertex-side balance metric (the
    imbalance stress tests aim a hot key at one shard and check the
    stabbing wave still answers exactly)."""
    sid = shard_of_vertices(np.asarray(keys, np.int32), n_shards)
    return np.bincount(sid, minlength=n_shards)
