"""Hash-prefix sharding of the graph tables across a device mesh.

The paper scales by letting every thread make progress against one shared
structure; the dataflow analogue scales by *partitioning* that structure
across devices.  This module is the routing layer that turns ``S``
unmodified per-shard :class:`~repro.core.types.GraphState` instances into
one graph (the decomposition arXiv 1809.00896 uses to keep reachability
queries independent of mutators, with the snapshot discipline of arXiv
2310.02380 at the cross-shard boundary).  See ``docs/ARCHITECTURE.md`` for
the paper-to-code map.

**Partition rule.**  An edge key ``(u, v)`` lives in shard
``edge_hash32(u, v) >> (32 - log2 S)`` — the top ``log2 S`` bits (the
*prefix*) of exactly the 32-bit hash whose low bits (the *suffix*,
``& (capacity - 1)``) the probe sequence already uses as the home slot
(:mod:`repro.core.hashing`).  Prefix and suffix are disjoint bit fields for
any per-shard capacity ≤ ``2**(32 - log2 S)``, so routing is independent of
within-shard probing and every shard runs the existing
``hash_probe`` locate, ``probe_place`` placement, and ``masked_compact``
rehash **unchanged** — no kernel knows sharding exists.

**Vertex replication.**  Edge ops must observe endpoint liveness *at their
own phase* (the paper's Fig. 3 stabbing subtlety), which a partitioned
vertex table cannot answer shard-locally.  The vertex table is therefore a
*deterministic replica*: every shard applies the identical vertex-op
sub-stream at the identical phase stamps.  The engines' vertex wave is
independent of edge ops, and :func:`route_ops` preserves batch shape (see
below), so the replicas — placement included — stay **byte-identical**
across shards and to the 1-shard graph (pinned by
``tests/test_sharding.py``).  Replication costs vertex memory ``S×``;
the edge table, the capacity-dominant structure (4× the vertex table at
default sizes), is what partitioning scales.

**Batch routing** (:func:`route_ops`).  Every shard receives the *full*
batch with non-owned edge *mutations* rewritten to the read-only
``OP_CONTAINS_EDGE`` rather than dropped.  Rewriting instead of dropping is
what makes replication exact: the FPSP conflict mask and both engines'
claim priorities depend on batch shape and edge-endpoint membership, so
every shard must see the identical silhouette.  A rewritten op can never
write (contains mutates nothing, and a non-owned key is never present in
the shard's edge table), and its result is discarded — per-op results are
gathered from the owner shard (edge ops) or shard 0 (vertex ops, all
replicas agree).

**Linearization** (mirroring the related papers' snapshot theorems): *a
cross-shard traversal snapshot is the fusion (:func:`fuse_csrs`) of the S
per-shard CSRs taken after all S shards installed their post-batch states;
since each shard's CSR linearizes at the same batch boundary and shards
partition the edge key space disjointly, the fused CSR is a consistent cut
of the whole graph at that boundary.*  Queries on the fused CSR
(``frontier`` / ``bfs`` / ``get_path``) run exactly as on a 1-shard CSR —
fusion concatenates the per-shard edge arrays with a shard-offset lane
remap and one stable re-sort, and the per-shard vertex columns are replicas
so slot identity is already global.

``WaitFreeGraph(n_shards=...)`` (:mod:`repro.core.graph`) owns the
host-side loop: route, apply per shard, gather results, grow per shard
(:mod:`repro.core.maintenance` rehash, synchronized so replicas stay
aligned).  ``n_shards=1`` bypasses this module entirely and is
bit-identical to the pre-sharding code path.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import edge_hash32_np
from .traversal import TraversalCSR
from .types import (
    EDGE_OPS,
    OP_ADD_EDGE,
    OP_CONTAINS_EDGE,
    OP_REMOVE_EDGE,
    GraphState,
    is_pow2,
    make_state,
)


def shard_of_edges(us: np.ndarray, vs: np.ndarray, n_shards: int) -> np.ndarray:
    """Owner shard per edge key: the top ``log2 n_shards`` bits (prefix) of
    the same 32-bit hash whose suffix is the probe home slot."""
    assert is_pow2(n_shards), "n_shards must be a power of two"
    us = np.asarray(us, np.int32)
    if n_shards == 1:
        return np.zeros(us.shape, np.int32)
    k = n_shards.bit_length() - 1
    return (edge_hash32_np(us, np.asarray(vs, np.int32)) >> np.uint32(32 - k)).astype(
        np.int32
    )


def route_ops(
    ops: np.ndarray, us: np.ndarray, vs: np.ndarray, n_shards: int
) -> Tuple[List[np.ndarray], np.ndarray]:
    """Per-shard op arrays + owner shard per lane.

    Shard ``s`` receives the full batch with non-owned edge mutations
    (AddE/RemE) rewritten to ``OP_CONTAINS_EDGE`` — same length, same
    ``(u, v, phase)`` lanes, same vertex/edge-op silhouette, so conflict
    masks and claim priorities are identical in every shard (the replica
    invariant; see the module docstring).  ``owner[i]`` is the shard whose
    result is authoritative for lane ``i`` (0 for vertex ops and NOPs).
    """
    ops = np.asarray(ops, np.int32)
    owner = np.zeros(ops.shape, np.int32)
    is_edge = np.isin(ops, EDGE_OPS)
    owner[is_edge] = shard_of_edges(us[is_edge], vs[is_edge], n_shards)
    is_emut = (ops == OP_ADD_EDGE) | (ops == OP_REMOVE_EDGE)
    shard_ops = []
    for s in range(n_shards):
        o = ops.copy()
        o[is_emut & (owner != s)] = OP_CONTAINS_EDGE
        shard_ops.append(o)
    return shard_ops, owner


def make_shard_states(
    v_capacity: int, e_shard_capacity: int, n_shards: int
) -> List[GraphState]:
    """Fresh empty shards: each carries the full-capacity vertex replica and
    a ``1/n_shards`` partition of the edge capacity."""
    return [make_state(v_capacity, e_shard_capacity) for _ in range(n_shards)]


# ---------------------------------------------------------------------------
# cross-shard snapshot fusion
# ---------------------------------------------------------------------------


@jax.jit
def _fuse_csrs_jit(csrs: Tuple[TraversalCSR, ...]) -> TraversalCSR:
    first = csrs[0]
    cv = first.v_key.shape[0]
    i32 = jnp.int32
    # shard-offset lane remap: global lane = shard offset + local lane (the
    # provenance a future cross-shard delta fold would splice against)
    offs = np.cumsum([0] + [c.src.shape[0] for c in csrs[:-1]])
    src = jnp.concatenate([c.src for c in csrs])
    dst = jnp.concatenate([c.dst for c in csrs])
    lane = jnp.concatenate([c.lane + i32(o) for c, o in zip(csrs, offs)])
    # per-shard invalid entries already carry src == Cv (the shared sentinel
    # — vertex capacity is a replica invariant), so one stable sort pushes
    # them all to the fused tail, exactly like build_csr's
    order = jnp.argsort(src, stable=True).astype(i32)
    src, dst, lane = src[order], dst[order], lane[order]
    rows = jnp.arange(cv, dtype=i32)
    return TraversalCSR(
        # vertex columns are byte-identical replicas: shard 0 speaks for all
        v_key=first.v_key,
        v_live=first.v_live,
        v_inc=first.v_inc,
        n_live=first.n_live,
        src=src,
        dst=dst,
        lane=lane,
        row_start=jnp.searchsorted(src, rows, side="left").astype(i32),
        row_end=jnp.searchsorted(src, rows, side="right").astype(i32),
        n_edges=sum(c.n_edges for c in csrs).astype(i32),
    )


def fuse_csrs(csrs: Sequence[TraversalCSR]) -> TraversalCSR:
    """Concatenate per-shard snapshots into one global CSR.

    The result is a plain :class:`~repro.core.traversal.TraversalCSR` —
    every traversal query (``reachable``/``bfs_parents``/``path_probe``/
    ``khop_mask``) runs on it exactly as on a 1-shard snapshot.  With one
    shard this is the identity (bit-identical to the pre-sharding path).
    Fused ``dst`` order within a row follows (shard, local lane) rather than
    the 1-shard global lane order; every query result is order-independent
    (scatter-*min*), so results — levels, parents, paths — are still
    byte-identical to the 1-shard graph's.
    """
    csrs = list(csrs)
    if len(csrs) == 1:
        return csrs[0]
    cv = csrs[0].v_capacity
    assert all(c.v_capacity == cv for c in csrs), "vertex replicas must agree"
    return _fuse_csrs_jit(tuple(csrs))


# ---------------------------------------------------------------------------
# mesh placement
# ---------------------------------------------------------------------------


def host_local_mesh() -> jax.sharding.Mesh:
    """A 1-D ``jax.sharding.Mesh`` over every local device (named
    ``"shard"``).  On single-device CPU this is the degenerate mesh the
    bit-identity tests pin the multi-shard path against; on a TPU slice the
    same code round-robins shards across real devices."""
    devs = np.asarray(jax.devices())
    return jax.sharding.Mesh(devs.reshape(-1), ("shard",))


def place_shards(
    states: Sequence[GraphState], mesh: Optional[jax.sharding.Mesh] = None
) -> List[GraphState]:
    """Pin shard ``i`` to mesh device ``i % n_devices`` (round-robin).

    Placement never changes values — shard states are pure pytrees — so it
    is a no-op semantically and a locality hint physically."""
    mesh = host_local_mesh() if mesh is None else mesh
    devs = list(mesh.devices.flat)
    return [jax.device_put(s, devs[i % len(devs)]) for i, s in enumerate(states)]


def edge_shard_histogram(
    ops: np.ndarray, us: np.ndarray, vs: np.ndarray, n_shards: int
) -> np.ndarray:
    """Edge-op count per shard for one batch — the balance metric (uniform
    keys → near-uniform prefixes; see ``workloads.shard_balance``)."""
    ops = np.asarray(ops, np.int32)
    mask = np.isin(ops, EDGE_OPS)
    sid = shard_of_edges(np.asarray(us, np.int32)[mask], np.asarray(vs, np.int32)[mask], n_shards)
    return np.bincount(sid, minlength=n_shards)
