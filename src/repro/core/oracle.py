"""Pure-Python sequential oracle for the graph's sequential specification.

This is the ground truth the concurrent engine is validated against
(linearizability: the engine's per-op results must equal the oracle's results
for the phase-ordered sequential application).

Semantics follow the paper's §2.1 on the *abstract* graph G=(V, E):

* ``remove_vertex(u)`` removes u and (abstractly) all incident edges — any
  later ``contains_edge``/``remove_edge`` touching u fails because u is not
  present, and re-adding u yields a vertex with *no* incident edges.  (The
  paper realizes this with fresh VNode allocation + endpoint revalidation,
  Fig. 3; we realize it with incarnation counters.)
"""

from __future__ import annotations

from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_NOP,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
)


class SequentialGraph:
    """Reference implementation: a plain sequential directed graph."""

    def __init__(self) -> None:
        self.vertices: Set[int] = set()
        self.edges: Set[Tuple[int, int]] = set()

    # -- the six operations (paper §2.1) --------------------------------
    def add_vertex(self, u: int) -> bool:
        if u in self.vertices:
            return False
        self.vertices.add(u)
        return True

    def remove_vertex(self, u: int) -> bool:
        if u not in self.vertices:
            return False
        self.vertices.discard(u)
        self.edges = {(a, b) for (a, b) in self.edges if a != u and b != u}
        return True

    def contains_vertex(self, u: int) -> bool:
        return u in self.vertices

    def add_edge(self, u: int, v: int) -> bool:
        if u not in self.vertices or v not in self.vertices:
            return False
        if (u, v) in self.edges:
            return False
        self.edges.add((u, v))
        return True

    def remove_edge(self, u: int, v: int) -> bool:
        if u not in self.vertices or v not in self.vertices:
            return False
        if (u, v) not in self.edges:
            return False
        self.edges.discard((u, v))
        return True

    def contains_edge(self, u: int, v: int) -> bool:
        if u not in self.vertices or v not in self.vertices:
            return False
        return (u, v) in self.edges

    # -- traversal queries (sequential specification) --------------------
    def bfs(self, u: int) -> Dict[int, int]:
        """BFS level map {vertex: hop distance} from u (u itself at 0).
        Empty when u is absent — matching the engine's dead-source rows."""
        if u not in self.vertices:
            return {}
        adj: Dict[int, List[int]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
        levels = {u: 0}
        q = deque([u])
        while q:
            a = q.popleft()
            for b in adj.get(a, ()):
                if b not in levels:
                    levels[b] = levels[a] + 1
                    q.append(b)
        return levels

    def reachable(self, u: int, v: int) -> bool:
        """Directed u ↝ v; u ↝ u is True iff u exists (the empty path)."""
        if u not in self.vertices or v not in self.vertices:
            return False
        return v in self.bfs(u)

    def khop(self, u: int, k: int) -> Set[int]:
        """Vertices within ≤k directed hops of u (including u)."""
        return {w for w, d in self.bfs(u).items() if d <= k}

    def path(self, u: int, v: int) -> Optional[List[int]]:
        """A shortest directed path u ↝ v as ``[u, ..., v]``, or None when
        unreachable / either endpoint absent.  ``path(u, u) == [u]`` when u
        exists (the empty path).  Ties between equal-length paths are broken
        arbitrarily — callers check validity + length, not the exact route
        (the engine's deterministic min-parent choice need not match)."""
        if u not in self.vertices or v not in self.vertices:
            return None
        adj: Dict[int, List[int]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
        parent = {u: u}
        q = deque([u])
        while q and v not in parent:
            a = q.popleft()
            for b in adj.get(a, ()):
                if b not in parent:
                    parent[b] = a
                    q.append(b)
        if v not in parent:
            return None
        chain = [v]
        while chain[-1] != u:
            chain.append(parent[chain[-1]])
        return list(reversed(chain))

    def apply(self, op: int, u: int, v: int) -> bool:
        if op == OP_ADD_VERTEX:
            return self.add_vertex(u)
        if op == OP_REMOVE_VERTEX:
            return self.remove_vertex(u)
        if op == OP_CONTAINS_VERTEX:
            return self.contains_vertex(u)
        if op == OP_ADD_EDGE:
            return self.add_edge(u, v)
        if op == OP_REMOVE_EDGE:
            return self.remove_edge(u, v)
        if op == OP_CONTAINS_EDGE:
            return self.contains_edge(u, v)
        if op == OP_NOP:
            return False
        raise ValueError(f"unknown op {op}")


def run_sequential(
    ops: Sequence[int],
    us: Sequence[int],
    vs: Sequence[int],
    phases: Sequence[int] | None = None,
    graph: SequentialGraph | None = None,
) -> Tuple[List[bool], SequentialGraph]:
    """Apply a batch sequentially in increasing phase order.

    Returns results in the *original* batch order (matching the engine).
    """
    n = len(ops)
    g = graph if graph is not None else SequentialGraph()
    order: Iterable[int]
    if phases is None:
        order = range(n)
    else:
        order = sorted(range(n), key=lambda i: phases[i])
    results: List[bool] = [False] * n
    for i in order:
        results[i] = g.apply(int(ops[i]), int(us[i]), int(vs[i]))
    return results, g
