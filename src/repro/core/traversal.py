"""Batched wait-free reachability + snapshot traversal engine.

The paper's graph answers the six membership operations; its lineage —
Chatterjee et al. (arXiv 1809.00896, non-blocking graph with reachability
queries) and Bhardwaj et al. (arXiv 2310.02380, wait-free snapshots) — shows
that *traversal* queries over a consistent snapshot are what real workloads
run on top.  This module is the dataflow analogue of their wait-free
``GetPath``/snapshot:

1. **Snapshot compaction** (:func:`build_csr`) — one jitted pass compacts the
   live, incarnation-valid edge set of a :class:`GraphState` into CSR form.
   Vertex identity is the *table slot* (stable within a state), so no key
   remapping is needed: edges resolve their endpoint slots via the same
   bounded-probe :func:`~repro.core.locate.locate_vertices` the engines use,
   stale bindings (incarnation mismatch — the Fig. 3 hazard) are masked out,
   survivors are sorted by source slot, and row offsets fall out of two
   ``searchsorted`` calls.  The CSR is a pure value: queries against it are
   trivially linearizable at the batch boundary of the state it was built
   from — every query in a batch observes the *same* post-batch graph.

2. **Incremental maintenance** (:func:`apply_delta`) — instead of throwing
   the CSR away after every update batch, fold the batch's effects into it:
   re-probe only the touched keys (one jitted locate over the batch, not the
   table), drop lanes invalidated by vertex churn, splice in the new edge
   lanes, and re-sort the O(batch)-sized delta into the surviving runs.  The
   result is bit-identical to ``build_csr`` on the post state; when a rehash
   moved the tables or the delta is a large fraction of the edge set, it
   falls back to the full rebuild automatically.

3. **Batched frontier BFS** (:func:`bfs_levels` / :func:`bfs_parents`) — a
   jitted ``lax.while_loop`` expands all S source frontiers simultaneously.
   Each level is one :func:`repro.kernels.frontier.frontier_expand` call —
   gather edge sources against the frontier, scatter-*min* the proposing
   source slot into edge destinations — so the same pass yields both the
   new frontier (hit iff min proposer < NBR_INF) and the BFS *parent* of
   every newly reached slot (the papers' ``GetPath`` pointer).  ``impl``
   selects the Pallas kernel, its interpret-mode twin, or the pure-jnp
   reference; all three are bit-identical.  The iteration count is bounded
   by the live vertex count (no path is longer), so the loop is
   bounded-depth — the traversal analogue of the engines' wait-free locate
   bound — and an edge-free snapshot skips the loop entirely.

4. **Query forms** — :func:`reachable` (pairwise u↝v for a whole batch),
   :func:`bfs_levels` (full level maps), :func:`bfs_parents` (levels +
   parent slots), :func:`path_probe` (everything ``GetPath`` reconstruction
   needs), :func:`khop_mask` (bounded-depth neighborhoods).  All are exact
   against :class:`repro.core.oracle` (see ``tests/test_traversal.py``).

**Linearization point** (the dataflow mirror of the related papers'
snapshot theorems): *every query against a ``TraversalCSR`` linearizes at
the boundary of the update batch whose post-state the CSR was built (or
delta-folded) from; all queries sharing one CSR observe the same abstract
graph, and no query observes a partially applied batch.*  This holds
because a CSR is a pure value compacted from one installed
:class:`~repro.core.types.GraphState` — there is no interleaving to
observe.  Under hash-prefix sharding the same statement holds for the
*fused* CSR (:func:`repro.core.sharding.fuse_partitioned`): every shard
installed its post-batch state before fusion, and shards partition both
key spaces disjointly, so the fusion — per-shard edge lanes validated
against the canonical global vertex directory — is a consistent cut at
the same batch boundary.

Host-side convenience wrappers (key-space in/out, batch bucketing, path
reconstruction) live on :class:`repro.core.graph.WaitFreeGraph`.  The
paper-to-code map for this module is ``docs/ARCHITECTURE.md``; the kernel
family contract behind :func:`frontier_expand` is ``docs/KERNELS.md``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.frontier import NBR_INF, frontier_expand

# ambient telemetry (no-op unless a registry is active — see repro.obs and
# docs/OBSERVABILITY.md; metrics imports nothing from repro.core)
from ..obs import metrics as obsm
from .locate import locate_edges, locate_vertices
from .types import (
    EMPTY_KEY,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    GraphState,
)

_NO_LEVEL = jnp.int32(-1)
_NO_PARENT = jnp.int32(-1)


class TraversalCSR(NamedTuple):
    """A compacted, consistent snapshot of one :class:`GraphState`.

    Vertices are identified by their slot in the originating vertex table
    (``0 .. Cv-1``); ``Cv`` itself is the sentinel slot for "no vertex".
    Edge arrays are sorted by ``src`` with invalid lanes pushed to the end
    (``src == dst == Cv``), so ``row_start/row_end`` delimit each slot's
    out-neighbor run.  ``lane`` records each entry's pre-sort edge-table
    lane — the provenance :func:`apply_delta` needs to splice update batches
    into the sorted arrays bit-identically to a full rebuild.
    """

    v_key: jnp.ndarray      # i32[Cv] — table keys (EMPTY_KEY where unused)
    v_live: jnp.ndarray     # bool[Cv]
    v_inc: jnp.ndarray      # i32[Cv] — incarnations (delta churn detection)
    n_live: jnp.ndarray     # i32[] — live vertex count (BFS depth bound)
    src: jnp.ndarray        # i32[Ce] — source slot per edge lane, sorted; Cv = invalid
    dst: jnp.ndarray        # i32[Ce] — destination slot, aligned with src
    lane: jnp.ndarray       # i32[Ce] — originating edge-table lane per entry
    row_start: jnp.ndarray  # i32[Cv] — CSR offsets into src/dst
    row_end: jnp.ndarray    # i32[Cv]
    n_edges: jnp.ndarray    # i32[] — valid edge count

    @property
    def v_capacity(self) -> int:
        return self.v_key.shape[0]

    @property
    def e_capacity(self) -> int:
        return self.src.shape[0]


def _edge_validity(state: GraphState):
    """Per-edge-lane validity — the Fig. 3 hazard mask shared by the CSR
    build and the snapshot: an edge lane is valid iff it is live, both
    endpoint keys locate to table slots, both endpoints are live, and both
    stored incarnations equal the endpoints' current incarnations (stale
    bindings from removed-and-re-added vertices are exactly the lanes this
    masks out).  Returns (src_slot, dst_slot, valid)."""
    has_edge = state.e_key_u != EMPTY_KEY
    loc_u = locate_vertices(state.v_key, state.e_key_u, has_edge & state.e_live)
    loc_v = locate_vertices(state.v_key, state.e_key_v, has_edge & state.e_live)
    su = jnp.where(loc_u.found, loc_u.slot, 0)
    sv = jnp.where(loc_v.found, loc_v.slot, 0)
    valid = (
        state.e_live
        & loc_u.found
        & loc_v.found
        & state.v_live[su]
        & state.v_live[sv]
        & (state.v_inc[su] == state.e_inc_u)
        & (state.v_inc[sv] == state.e_inc_v)
    )
    return su, sv, valid


@jax.jit
def build_csr(state: GraphState) -> TraversalCSR:
    """Compact the live, incarnation-valid edge set into CSR form
    (validity per :func:`_edge_validity`)."""
    cv = state.v_key.shape[0]
    su, sv, valid = _edge_validity(state)

    src = jnp.where(valid, su, cv).astype(jnp.int32)
    dst = jnp.where(valid, sv, cv).astype(jnp.int32)
    order = jnp.argsort(src, stable=True).astype(jnp.int32)
    src = src[order]
    dst = dst[order]

    rows = jnp.arange(cv, dtype=jnp.int32)
    row_start = jnp.searchsorted(src, rows, side="left").astype(jnp.int32)
    row_end = jnp.searchsorted(src, rows, side="right").astype(jnp.int32)

    return TraversalCSR(
        v_key=state.v_key,
        v_live=state.v_live,
        v_inc=state.v_inc,
        n_live=jnp.sum(state.v_live).astype(jnp.int32),
        src=src,
        dst=dst,
        lane=order,
        row_start=row_start,
        row_end=row_end,
        n_edges=jnp.sum(valid).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# incremental CSR maintenance
# ---------------------------------------------------------------------------

def _pad_pow2(a: np.ndarray, fill: int, floor: int = 16) -> np.ndarray:
    """Pad to a power-of-two bucket so the jitted delta probe compiles once
    per bucket, not once per batch size (same trick as the engines)."""
    n = a.shape[0]
    bucket = max(floor, 1 << max(n - 1, 1).bit_length())
    out = np.full(bucket, fill, a.dtype)
    out[:n] = a
    return out


class DeltaProbe(NamedTuple):
    """Everything a delta fold needs to know about the touched keys, as
    resolved against the *post* state (all device arrays)."""

    v_found: jnp.ndarray     # bool[nv] — touched vertex key present (live or tomb)
    v_slot: jnp.ndarray      # i32[nv]
    v_live_now: jnp.ndarray  # bool[nv]
    v_inc_now: jnp.ndarray   # i32[nv]
    e_found: jnp.ndarray     # bool[ne] — touched edge key has a table lane
    e_lane: jnp.ndarray      # i32[ne]
    e_valid: jnp.ndarray     # bool[ne] — lane live + incarnation-valid now
    e_su: jnp.ndarray        # i32[ne] — endpoint slots (where e_found)
    e_sv: jnp.ndarray        # i32[ne]
    n_live: jnp.ndarray      # i32[] — post-state live vertex count


def _delta_probe_parts(
    state: GraphState, vkeys: jnp.ndarray, eus: jnp.ndarray, evs: jnp.ndarray
) -> DeltaProbe:
    """Resolve the touched keys against the post state: vertex slots +
    liveness + incarnations, edge lanes + endpoint slots + validity, and the
    new live count.  O(batch) probes instead of ``build_csr``'s O(capacity).
    Shared by the packed host transfer (:func:`_delta_probe`) and the fused
    device merge (:func:`repro.core.maintenance.delta_merge`)."""
    vloc = locate_vertices(state.v_key, vkeys, vkeys != EMPTY_KEY)
    v_safe = jnp.where(vloc.found, vloc.slot, 0)

    e_active = eus != EMPTY_KEY
    eloc = locate_edges(state.e_key_u, state.e_key_v, eus, evs, e_active)
    e_safe = jnp.where(eloc.found, eloc.slot, 0)
    lu = locate_vertices(state.v_key, eus, eloc.found)
    lv = locate_vertices(state.v_key, evs, eloc.found)
    su = jnp.where(lu.found, lu.slot, 0)
    sv = jnp.where(lv.found, lv.slot, 0)
    e_valid = (
        eloc.found
        & state.e_live[e_safe]
        & lu.found
        & lv.found
        & state.v_live[su]
        & state.v_live[sv]
        & (state.v_inc[su] == state.e_inc_u[e_safe])
        & (state.v_inc[sv] == state.e_inc_v[e_safe])
    )
    return DeltaProbe(
        v_found=vloc.found,
        v_slot=v_safe.astype(jnp.int32),
        v_live_now=state.v_live[v_safe],
        v_inc_now=state.v_inc[v_safe],
        e_found=eloc.found,
        e_lane=e_safe.astype(jnp.int32),
        e_valid=e_valid,
        e_su=su.astype(jnp.int32),
        e_sv=sv.astype(jnp.int32),
        n_live=jnp.sum(state.v_live).astype(jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("nv", "ne"))
def _delta_probe(state: GraphState, pack: jnp.ndarray, nv: int, ne: int):
    """Packed-transfer wrapper around :func:`_delta_probe_parts` for the host
    splice path.  The touched keys arrive as one packed i32 buffer
    (vkeys | e_us | e_vs, each padded to a power-of-two bucket) — a single
    host-to-device transfer; per-array device_puts were the dominant cost of
    the delta path on CPU."""
    p = _delta_probe_parts(state, pack[:nv], pack[nv:nv + ne], pack[nv + ne:])
    # one packed i32 result (bools widened) = one device-to-host transfer;
    # n_live stays a device scalar — it goes straight back into the CSR
    out = jnp.concatenate(
        [
            p.v_found.astype(jnp.int32),
            p.v_slot,
            p.v_live_now.astype(jnp.int32),
            p.v_inc_now,
            p.e_found.astype(jnp.int32),
            p.e_lane,
            p.e_valid.astype(jnp.int32),
            p.e_su,
            p.e_sv,
        ]
    )
    return out, p.n_live


@functools.partial(jax.jit, static_argnames=("ce", "cv"))
def _delta_splice(pack: jnp.ndarray, ce: int, cv: int):
    """Unpack the host-assembled sorted edge arrays (one transfer) and derive
    the row offsets on device — the same ``searchsorted`` calls as
    :func:`build_csr`, so the delta result is bit-identical by construction."""
    src = pack[:ce]
    dst = pack[ce:2 * ce]
    lane = pack[2 * ce:3 * ce]
    n_edges = pack[3 * ce]
    rows = jnp.arange(cv, dtype=jnp.int32)
    row_start = jnp.searchsorted(src, rows, side="left").astype(jnp.int32)
    row_end = jnp.searchsorted(src, rows, side="right").astype(jnp.int32)
    return src, dst, lane, row_start, row_end, n_edges


def apply_delta(
    csr: TraversalCSR,
    state: GraphState,
    ops,
    us,
    vs=None,
    *,
    max_delta_frac: float = 0.25,
    impl: Optional[str] = None,
) -> TraversalCSR:
    """Fold one applied update batch into an existing snapshot.

    ``csr`` must be the snapshot of the pre-batch state and ``state`` the
    post-batch state the engine returned for ``(ops, us, vs)``.  The result
    is **bit-identical** to ``build_csr(state)`` — same sorted edge arrays,
    same lane provenance, same offsets.  The probe side is O(batch) (one
    jitted locate over the touched keys instead of the whole table).

    ``impl`` picks the splice side (``None`` = auto: device on TPU, host
    elsewhere — ``maintenance.resolve_impl``):

    * ``"device"`` / ``"device_interpret"`` — the whole fold is one fused
      jitted pass (:func:`repro.core.maintenance.delta_merge`): prefix-sum
      compaction of the surviving lanes, a sort of the O(batch) delta
      (bucketed shapes, so it compiles once per bucket), and a device-side
      ``searchsorted`` merge into the surviving runs.  One host-to-device
      transfer (the packed touched keys), zero transfers back — the host
      lexsort round-trip this path replaces was the dominant refresh cost.
    * ``"host"`` — the numpy splice: mask updates and a lexsort over the
      surviving lanes on the host (O(valid edges) with small vectorized
      constants).  Kept as the oracle the device merge is tested
      bit-identical against, and as the fallback when the composite merge
      keys would overflow int32 (``maintenance.merge_keys_fit``).

    Falls back to :func:`build_csr` automatically when

    * either table capacity changed (a growth rehash moved every slot), or
    * the touched-key footprint exceeds ``max_delta_frac`` of the edge
      capacity (re-sorting the delta would approach the full rebuild).

    The reconciliation is *result-blind*: it re-probes the touched keys
    against the post state rather than trusting per-op success bits, so
    duplicate ops, failed ops, and within-batch remove/re-add churn are all
    handled by construction.
    """
    ce = csr.e_capacity
    if state.v_capacity != csr.v_capacity or state.e_capacity != ce:
        obsm.counter("csr.delta.rebuild_capacity_changed")
        return build_csr(state)  # rehash: every slot moved

    ops = np.asarray(ops, np.int32)
    us = np.asarray(us, np.int32)
    vs = np.zeros_like(us) if vs is None else np.asarray(vs, np.int32)

    # dedup touched keys (cheap int64 codes beat np.unique(axis=1) here)
    v_touch = np.unique(us[(ops == OP_ADD_VERTEX) | (ops == OP_REMOVE_VERTEX)])
    e_mask = (ops == OP_ADD_EDGE) | (ops == OP_REMOVE_EDGE)
    e_code = np.unique(
        (us[e_mask].astype(np.int64) << 32) | (vs[e_mask].astype(np.int64) & 0xFFFFFFFF)
    )
    e_tu = (e_code >> 32).astype(np.int32)
    e_tv = e_code.astype(np.int32)
    if v_touch.size == 0 and e_code.size == 0:
        obsm.counter("csr.delta.readonly")
        return csr  # read-only batch: the snapshot is still exact
    if v_touch.size + e_code.size > max(32, int(max_delta_frac * ce)):
        obsm.counter("csr.delta.rebuild_too_large")
        return build_csr(state)  # delta too large to beat the rebuild
    obsm.counter("csr.delta.folded")
    obsm.hist("csr.delta.touched", int(v_touch.size + e_code.size))

    v_pad = _pad_pow2(v_touch.astype(np.int32), int(EMPTY_KEY))
    eu_pad = _pad_pow2(e_tu, int(EMPTY_KEY))
    ev_pad = _pad_pow2(e_tv, 0)
    nvp, nep = v_pad.shape[0], eu_pad.shape[0]

    from . import maintenance  # deferred: maintenance imports this module

    if maintenance.resolve_impl(impl) != "host":
        if maintenance.merge_keys_fit(csr.v_capacity, ce):
            return maintenance.delta_merge(
                csr,
                state,
                np.concatenate([v_pad, eu_pad, ev_pad]),
                nvp,
                nep,
                impl=impl,
            )
        # composite merge keys would overflow int32: host splice below

    packed, n_live = _delta_probe(
        state, np.concatenate([v_pad, eu_pad, ev_pad]), nvp, nep
    )
    packed = np.asarray(packed)
    nv, ne = v_touch.size, e_code.size
    v_found = packed[:nv].astype(bool)
    v_slot = packed[nvp:nvp + nv]
    v_live_now = packed[2 * nvp:2 * nvp + nv].astype(bool)
    v_inc_now = packed[3 * nvp:3 * nvp + nv]
    eoff = 4 * nvp
    e_found = packed[eoff:eoff + ne].astype(bool)
    e_lane = packed[eoff + nep:eoff + nep + ne]
    e_valid = packed[eoff + 2 * nep:eoff + 2 * nep + ne].astype(bool)
    e_su = packed[eoff + 3 * nep:eoff + 3 * nep + ne]
    e_sv = packed[eoff + 4 * nep:eoff + 4 * nep + ne]

    # vertices whose (live, inc) changed invalidate every lane bound to them
    pre_live = np.asarray(csr.v_live)
    pre_inc = np.asarray(csr.v_inc)
    vsl = v_slot[v_found]
    changed = vsl[(pre_live[vsl] != v_live_now[v_found])
                  | (pre_inc[vsl] != v_inc_now[v_found])]

    n_e = int(csr.n_edges)
    src_v = np.asarray(csr.src)[:n_e]
    dst_v = np.asarray(csr.dst)[:n_e]
    lane_v = np.asarray(csr.lane)[:n_e]

    keep = np.ones(n_e, bool)
    if changed.size:
        hit = np.zeros(csr.v_capacity + 1, bool)
        hit[changed] = True
        keep &= ~(hit[src_v] | hit[dst_v])
    touched_lanes = e_lane[e_found]
    if touched_lanes.size:
        # every touched edge key is re-derived from the post state below;
        # drop its old entry (if any) so the splice is the single source
        lhit = np.zeros(ce, bool)
        lhit[touched_lanes] = True
        keep &= ~lhit[lane_v]

    ins = e_found & e_valid
    new_src = e_su[ins].astype(np.int32)
    new_dst = e_sv[ins].astype(np.int32)
    new_lane = e_lane[ins].astype(np.int32)

    src_all = np.concatenate([src_v[keep], new_src])
    dst_all = np.concatenate([dst_v[keep], new_dst])
    lane_all = np.concatenate([lane_v[keep], new_lane])
    order = np.lexsort((lane_all, src_all))  # == build_csr's stable sort by src
    src_all, dst_all, lane_all = src_all[order], dst_all[order], lane_all[order]

    cv = csr.v_capacity
    n_valid = src_all.shape[0]
    lane_used = np.zeros(ce, bool)
    lane_used[lane_all] = True
    tail_lane = np.nonzero(~lane_used)[0].astype(np.int32)  # ascending, as argsort leaves it
    invalid = np.full(ce - n_valid, cv, np.int32)
    pack = np.concatenate(
        [src_all, invalid, dst_all, invalid, lane_all, tail_lane,
         np.asarray([n_valid], np.int32)]
    )
    src, dst, lane, row_start, row_end, n_edges = _delta_splice(pack, ce, cv)

    return TraversalCSR(
        v_key=state.v_key,
        v_live=state.v_live,
        v_inc=state.v_inc,
        n_live=n_live,
        src=src,
        dst=dst,
        lane=lane,
        row_start=row_start,
        row_end=row_end,
        n_edges=n_edges,
    )


# ---------------------------------------------------------------------------
# batched frontier BFS
# ---------------------------------------------------------------------------


def _locate_live_slots(csr: TraversalCSR, keys: jnp.ndarray):
    """Map query keys to live slots; returns (slot, is_live) with slot=Cv when
    absent/dead.  EMPTY_KEY query lanes (batch padding) resolve to dead."""
    active = keys != EMPTY_KEY
    loc = locate_vertices(csr.v_key, keys, active)
    safe = jnp.where(loc.found, loc.slot, 0)
    live = loc.found & csr.v_live[safe]
    slot = jnp.where(live, loc.slot, csr.v_capacity).astype(jnp.int32)
    return slot, live


def _bfs_from_slots(csr: TraversalCSR, slot: jnp.ndarray, live: jnp.ndarray, impl: Optional[str]):
    """The frontier loop, from already-located source slots (callers resolve
    each endpoint set exactly once — see :func:`reachable`).  Returns
    (levels, parents): i32[S, Cv] each, -1 for unreached / no parent.

    One :func:`frontier_expand` per level: the scatter-min result is both
    the discovery mask (min < NBR_INF) and the parent pointer of every
    newly reached slot.  An ``n_edges == 0`` snapshot returns the source-
    only maps without entering the loop at all.
    """
    cv = csr.v_capacity
    n_src = slot.shape[0]

    # one extra column absorbs sentinel slot Cv (invalid edges / dead sources)
    frontier = jnp.zeros((n_src, cv + 1), bool)
    frontier = frontier.at[jnp.arange(n_src), slot].set(live)
    levels = jnp.full((n_src, cv + 1), _NO_LEVEL)
    levels = jnp.where(frontier, 0, levels)
    parents = jnp.full((n_src, cv + 1), _NO_PARENT)

    def cond(carry):
        _, _, frontier, depth = carry
        return jnp.any(frontier[:, :cv]) & (depth < csr.n_live)

    def body(carry):
        levels, parents, frontier, depth = carry
        nbr = frontier_expand(frontier, csr.src, csr.dst, impl=impl)
        new = (nbr != NBR_INF) & (levels == _NO_LEVEL)
        new = new.at[:, cv].set(False)
        levels = jnp.where(new, depth + 1, levels)
        parents = jnp.where(new, nbr, parents)
        return levels, parents, new, depth + 1

    init = (levels, parents, frontier, jnp.int32(0))
    levels, parents, _, _ = jax.lax.cond(
        csr.n_edges == 0,
        lambda c: c,  # edge-free snapshot: sources are the whole answer
        lambda c: jax.lax.while_loop(cond, body, c),
        init,
    )
    return levels[:, :cv], parents[:, :cv]


@functools.partial(jax.jit, static_argnames=("impl",))
def bfs_parents(csr: TraversalCSR, src_keys: jnp.ndarray, impl: Optional[str] = None):
    """Batched BFS with parent pointers: (levels, parents), i32[S, Cv] each.

    ``levels[s, j]`` is the hop distance from ``src_keys[s]`` to the vertex
    in slot ``j`` (0 for the source itself, -1 unreachable); ``parents[s, j]``
    is the slot the BFS reached ``j`` from (-1 for sources and unreached
    slots).  Parents are deterministic: the minimum frontier source slot
    among ``j``'s in-edges, identical across kernel/reference impls.
    """
    slot, live = _locate_live_slots(csr, src_keys)
    return _bfs_from_slots(csr, slot, live, impl)


@functools.partial(jax.jit, static_argnames=("impl",))
def bfs_levels(
    csr: TraversalCSR, src_keys: jnp.ndarray, impl: Optional[str] = None
) -> jnp.ndarray:
    """Batched BFS level map: i32[S, Cv], -1 = unreachable.

    Sources that are absent, dead, or EMPTY_KEY padding yield all -1 rows.
    """
    return bfs_parents(csr, src_keys, impl=impl)[0]


@functools.partial(jax.jit, static_argnames=("impl",))
def reachable(
    csr: TraversalCSR, us: jnp.ndarray, vs: jnp.ndarray, impl: Optional[str] = None
) -> jnp.ndarray:
    """Batched reachability: bool[B], ``us[i] ↝ vs[i]`` by directed paths.

    False when either endpoint is absent/dead; ``u ↝ u`` is True iff u is
    live (the empty path).  Every pair is answered against the same snapshot.
    Each endpoint set is located exactly once: sources feed the frontier
    loop directly, targets only index the finished level map.
    """
    uslot, ulive = _locate_live_slots(csr, us)
    vslot, vlive = _locate_live_slots(csr, vs)
    levels, _ = _bfs_from_slots(csr, uslot, ulive, impl)
    safe = jnp.where(vlive, vslot, 0)
    return vlive & (levels[jnp.arange(us.shape[0]), safe] >= 0)


def _canonical_parents(csr: TraversalCSR, levels: jnp.ndarray) -> jnp.ndarray:
    """Rewrite BFS parents to the minimum-*key* predecessor on a shortest
    path (one scatter-min over the edge list).

    ``_bfs_from_slots``'s parents are the minimum frontier *slot*, which is
    layout-dependent: the same abstract graph held at different shard
    counts (or after a rehash) numbers slots differently, so when several
    shortest paths exist the reconstructed path would differ.  Keys are
    layout-invariant, so min-key parents make ``GetPath`` canonical —
    identical key sequences for ``n_shards ∈ {1, 2, 4}`` by construction."""
    cv = csr.v_capacity
    i32 = jnp.int32
    big = jnp.iinfo(jnp.int32).max
    n_src = levels.shape[0]

    # rank slots by key (live keys are unique; dead slots sort to the tail)
    order = jnp.argsort(jnp.where(csr.v_live, csr.v_key, big)).astype(i32)
    rank = jnp.zeros(cv, i32).at[order].set(jnp.arange(cv, dtype=i32))

    # sentinel column cv absorbs invalid edge lanes (src == dst == cv)
    lv = jnp.concatenate([levels, jnp.full((n_src, 1), _NO_LEVEL)], axis=1)
    ls = lv[:, csr.src]
    ld = lv[:, csr.dst]
    on_path = (ls >= 0) & (ld == ls + 1)
    cand = jnp.where(on_path, rank[jnp.clip(csr.src, 0, cv - 1)], big)
    best = jnp.full((n_src, cv + 1), big, i32)
    best = best.at[jnp.arange(n_src, dtype=i32)[:, None], csr.dst[None, :]].min(cand)
    best = best[:, :cv]
    parent = jnp.where(
        (best < big) & (levels > 0), order[jnp.clip(best, 0, cv - 1)], _NO_PARENT
    )
    return parent


@functools.partial(jax.jit, static_argnames=("impl",))
def path_probe(
    csr: TraversalCSR, us: jnp.ndarray, vs: jnp.ndarray, impl: Optional[str] = None
):
    """Device half of ``GetPath``: (levels, parents, target_slot, target_live).

    One locate per endpoint set, one BFS for the whole batch; the host walks
    ``parents`` back from ``target_slot`` to reconstruct explicit key-space
    paths (:meth:`repro.core.graph.WaitFreeGraph.get_path`).  Parents are
    canonicalized to the minimum-key shortest-path predecessor
    (:func:`_canonical_parents`), so the reconstructed path is identical
    across table layouts — in particular across shard counts."""
    uslot, ulive = _locate_live_slots(csr, us)
    vslot, vlive = _locate_live_slots(csr, vs)
    levels, _ = _bfs_from_slots(csr, uslot, ulive, impl)
    return levels, _canonical_parents(csr, levels), vslot, vlive


@functools.partial(jax.jit, static_argnames=("impl",))
def khop_mask(
    csr: TraversalCSR, src_keys: jnp.ndarray, k: jnp.ndarray, impl: Optional[str] = None
) -> jnp.ndarray:
    """bool[S, Cv]: slots within ≤k directed hops of each source (incl. self)."""
    levels = bfs_levels(csr, src_keys, impl=impl)
    return (levels >= 0) & (levels <= jnp.asarray(k, jnp.int32))


@jax.jit
def snapshot_live(state: GraphState):
    """Device-side snapshot masks: (v_live_mask, e_valid_mask).

    ``e_valid_mask`` marks edge lanes that are live AND bound to both
    endpoints' current incarnations — the same :func:`_edge_validity`
    predicate the CSR build uses, exposed for vectorized host snapshots."""
    _, _, e_valid = _edge_validity(state)
    return state.v_live, e_valid
