"""Batched wait-free reachability + snapshot traversal engine.

The paper's graph answers the six membership operations; its lineage —
Chatterjee et al. (arXiv 1809.00896, non-blocking graph with reachability
queries) and Bhardwaj et al. (arXiv 2310.02380, wait-free snapshots) — shows
that *traversal* queries over a consistent snapshot are what real workloads
run on top.  This module is the dataflow analogue of their wait-free
``GetPath``/snapshot:

1. **Snapshot compaction** (:func:`build_csr`) — one jitted pass compacts the
   live, incarnation-valid edge set of a :class:`GraphState` into CSR form.
   Vertex identity is the *table slot* (stable within a state), so no key
   remapping is needed: edges resolve their endpoint slots via the same
   bounded-probe :func:`~repro.core.locate.locate_vertices` the engines use,
   stale bindings (incarnation mismatch — the Fig. 3 hazard) are masked out,
   survivors are sorted by source slot, and row offsets fall out of two
   ``searchsorted`` calls.  The CSR is a pure value: queries against it are
   trivially linearizable at the batch boundary of the state it was built
   from — every query in a batch observes the *same* post-batch graph.

2. **Batched frontier BFS** (:func:`bfs_levels`) — a jitted
   ``lax.while_loop`` expands all S source frontiers simultaneously:
   one gather (edge source slots vs. frontier) + one scatter-max (edge
   destination slots) per level.  The iteration count is bounded by the live
   vertex count (no path is longer), so the loop is bounded-depth — the
   traversal analogue of the engines' wait-free locate bound.

3. **Query forms** — :func:`reachable` (pairwise u↝v for a whole batch),
   :func:`bfs_levels` (full level maps), :func:`khop_mask` (bounded-depth
   neighborhoods).  All are exact against :class:`repro.core.oracle`
   (see ``tests/test_traversal.py``).

Host-side convenience wrappers (key-space in/out, batch bucketing) live on
:class:`repro.core.graph.WaitFreeGraph`.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .locate import locate_vertices
from .types import EMPTY_KEY, GraphState

_NO_LEVEL = jnp.int32(-1)


class TraversalCSR(NamedTuple):
    """A compacted, consistent snapshot of one :class:`GraphState`.

    Vertices are identified by their slot in the originating vertex table
    (``0 .. Cv-1``); ``Cv`` itself is the sentinel slot for "no vertex".
    Edge arrays are sorted by ``src`` with invalid lanes pushed to the end
    (``src == dst == Cv``), so ``row_start/row_end`` delimit each slot's
    out-neighbor run.
    """

    v_key: jnp.ndarray      # i32[Cv] — table keys (EMPTY_KEY where unused)
    v_live: jnp.ndarray     # bool[Cv]
    n_live: jnp.ndarray     # i32[] — live vertex count (BFS depth bound)
    src: jnp.ndarray        # i32[Ce] — source slot per edge lane, sorted; Cv = invalid
    dst: jnp.ndarray        # i32[Ce] — destination slot, aligned with src
    row_start: jnp.ndarray  # i32[Cv] — CSR offsets into src/dst
    row_end: jnp.ndarray    # i32[Cv]
    n_edges: jnp.ndarray    # i32[] — valid edge count

    @property
    def v_capacity(self) -> int:
        return self.v_key.shape[0]


def _edge_validity(state: GraphState):
    """Per-edge-lane validity — the Fig. 3 hazard mask shared by the CSR
    build and the snapshot: an edge lane is valid iff it is live, both
    endpoint keys locate to table slots, both endpoints are live, and both
    stored incarnations equal the endpoints' current incarnations (stale
    bindings from removed-and-re-added vertices are exactly the lanes this
    masks out).  Returns (src_slot, dst_slot, valid)."""
    has_edge = state.e_key_u != EMPTY_KEY
    loc_u = locate_vertices(state.v_key, state.e_key_u, has_edge & state.e_live)
    loc_v = locate_vertices(state.v_key, state.e_key_v, has_edge & state.e_live)
    su = jnp.where(loc_u.found, loc_u.slot, 0)
    sv = jnp.where(loc_v.found, loc_v.slot, 0)
    valid = (
        state.e_live
        & loc_u.found
        & loc_v.found
        & state.v_live[su]
        & state.v_live[sv]
        & (state.v_inc[su] == state.e_inc_u)
        & (state.v_inc[sv] == state.e_inc_v)
    )
    return su, sv, valid


@jax.jit
def build_csr(state: GraphState) -> TraversalCSR:
    """Compact the live, incarnation-valid edge set into CSR form
    (validity per :func:`_edge_validity`)."""
    cv = state.v_key.shape[0]
    su, sv, valid = _edge_validity(state)

    src = jnp.where(valid, su, cv).astype(jnp.int32)
    dst = jnp.where(valid, sv, cv).astype(jnp.int32)
    order = jnp.argsort(src, stable=True)
    src = src[order]
    dst = dst[order]

    rows = jnp.arange(cv, dtype=jnp.int32)
    row_start = jnp.searchsorted(src, rows, side="left").astype(jnp.int32)
    row_end = jnp.searchsorted(src, rows, side="right").astype(jnp.int32)

    return TraversalCSR(
        v_key=state.v_key,
        v_live=state.v_live,
        n_live=jnp.sum(state.v_live).astype(jnp.int32),
        src=src,
        dst=dst,
        row_start=row_start,
        row_end=row_end,
        n_edges=jnp.sum(valid).astype(jnp.int32),
    )


def _locate_live_slots(csr: TraversalCSR, keys: jnp.ndarray):
    """Map query keys to live slots; returns (slot, is_live) with slot=Cv when
    absent/dead.  EMPTY_KEY query lanes (batch padding) resolve to dead."""
    active = keys != EMPTY_KEY
    loc = locate_vertices(csr.v_key, keys, active)
    safe = jnp.where(loc.found, loc.slot, 0)
    live = loc.found & csr.v_live[safe]
    slot = jnp.where(live, loc.slot, csr.v_capacity).astype(jnp.int32)
    return slot, live


@jax.jit
def bfs_levels(csr: TraversalCSR, src_keys: jnp.ndarray) -> jnp.ndarray:
    """Batched BFS level map: i32[S, Cv], -1 = unreachable.

    ``levels[s, j]`` is the hop distance from ``src_keys[s]`` to the vertex
    in slot ``j`` (0 for the source itself).  Sources that are absent, dead,
    or EMPTY_KEY padding yield all -1 rows.  One frontier expansion per loop
    iteration: gather edge sources against the frontier, scatter-max into
    edge destinations; the loop is capped at the live-vertex count.
    """
    cv = csr.v_capacity
    n_src = src_keys.shape[0]
    slot, live = _locate_live_slots(csr, src_keys)

    # one extra column absorbs sentinel slot Cv (invalid edges / dead sources)
    frontier = jnp.zeros((n_src, cv + 1), bool)
    frontier = frontier.at[jnp.arange(n_src), slot].set(live)
    levels = jnp.full((n_src, cv + 1), _NO_LEVEL)
    levels = jnp.where(frontier, 0, levels)

    def cond(carry):
        _, frontier, depth = carry
        return jnp.any(frontier[:, :cv]) & (depth < csr.n_live)

    def body(carry):
        levels, frontier, depth = carry
        on_edge = frontier[:, csr.src]                       # bool[S, Ce]
        hit = jnp.zeros((n_src, cv + 1), bool).at[:, csr.dst].max(on_edge)
        new = hit & (levels == _NO_LEVEL)
        new = new.at[:, cv].set(False)
        levels = jnp.where(new, depth + 1, levels)
        return levels, new, depth + 1

    levels, _, _ = jax.lax.while_loop(cond, body, (levels, frontier, jnp.int32(0)))
    return levels[:, :cv]


@jax.jit
def reachable(csr: TraversalCSR, us: jnp.ndarray, vs: jnp.ndarray) -> jnp.ndarray:
    """Batched reachability: bool[B], ``us[i] ↝ vs[i]`` by directed paths.

    False when either endpoint is absent/dead; ``u ↝ u`` is True iff u is
    live (the empty path).  Every pair is answered against the same snapshot.
    """
    levels = bfs_levels(csr, us)
    dslot, dlive = _locate_live_slots(csr, vs)
    safe = jnp.where(dlive, dslot, 0)
    return dlive & (levels[jnp.arange(us.shape[0]), safe] >= 0)


@jax.jit
def khop_mask(csr: TraversalCSR, src_keys: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """bool[S, Cv]: slots within ≤k directed hops of each source (incl. self)."""
    levels = bfs_levels(csr, src_keys)
    return (levels >= 0) & (levels <= jnp.asarray(k, jnp.int32))


@jax.jit
def snapshot_live(state: GraphState):
    """Device-side snapshot masks: (v_live_mask, e_valid_mask).

    ``e_valid_mask`` marks edge lanes that are live AND bound to both
    endpoints' current incarnations — the same :func:`_edge_validity`
    predicate the CSR build uses, exposed for vectorized host snapshots."""
    _, _, e_valid = _edge_validity(state)
    return state.v_live, e_valid
