"""The wait-free batch-combine engine — the paper's contribution, in dataflow.

``apply_batch(state, batch)`` resolves an entire ODA (a batch of published
operation descriptors) in one bounded-depth pass, producing exactly the
results of applying the ops sequentially in phase order (validated op-by-op
against ``repro.core.oracle``).  Structure:

  A. **Vertex wave** — locate every vertex key; sort vertex ops by
     (key, phase); the liveness evolution of one key under its ops is a
     2-state DFA whose transitions (const/id function pairs) compose
     associatively, so one ``associative_scan`` resolves *all* keys' op
     groups simultaneously.  This is the helping mechanism: every lane
     computes the outcome of every conflicting op — in O(log n) depth
     regardless of contention (the wait-free bound).

  B. **Stabbing wave** — edge ops must observe endpoint liveness *at their
     own phase* (the paper's Fig. 3 subtlety: edge linearization points lie
     outside the edge method, determined by concurrent vertex ops).  A merged
     (key, phase)-sorted scan over vertex transitions + per-edge-op endpoint
     queries answers "was u live, and at which incarnation, at phase p?" for
     all 2n endpoint queries at once.

  C. **Edge wave** — edge ops sorted by (u, v, phase) split into *epochs*:
     maximal runs where both endpoints are continuously live at fixed
     incarnations (epochs are provably contiguous in phase order because
     incarnations only grow).  Within an epoch, edge validity is a 1-bit DFA
     — again const/id transitions, again one associative_scan.  Stored
     bindings only match an epoch seed when both stored incarnations equal
     the epoch's (physical stale-edge cleanup falls out for free).

  D. Scatter results back to original batch order; write back final table
     states; insert brand-new keys via deterministic scatter-claim.

Everything is int32/bool — results are asserted *exactly* equal to the
oracle, not allclose.

Each op linearizes at its phase stamp: a batch's results are exactly those
of the phase-ordered sequential execution.  Where this engine sits in the
paper-to-code map — and how sharding runs it unchanged per shard — is
``docs/ARCHITECTURE.md``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .locate import claim_edge_slots, claim_vertex_slots, locate_edges, locate_vertices
from .scanutils import scan_fnpairs, scan_last_set, seg_cumsum_exclusive, shift_right
from .types import (
    ABSENT_INC,
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_CONTAINS_EDGE,
    OP_CONTAINS_VERTEX,
    OP_REMOVE_EDGE,
    OP_REMOVE_VERTEX,
    ApplyResult,
    GraphState,
    OpBatch,
)

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _sort_by(keys, *arrays):
    """Stable sort of arrays by key tuple (major first); returns perm + sorted.

    Multi-key lexsort avoids packing composite keys into int64 (JAX runs with
    x64 disabled by default, which would silently truncate the pack).
    """
    perm = jnp.lexsort(tuple(reversed(keys)))
    return perm, tuple(a[perm] for a in arrays)


# ---------------------------------------------------------------------------
# A. vertex wave
# ---------------------------------------------------------------------------

def _vertex_wave(state: GraphState, batch: OpBatch):
    op, u, phase = batch.op, batch.u, batch.phase
    n = op.shape[0]

    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    vkey = jnp.where(is_vop, u, _INT32_MAX)

    loc = locate_vertices(state.v_key, vkey, is_vop)
    init_live = jnp.where(loc.found, state.v_live[jnp.where(loc.found, loc.slot, 0)], False)
    init_inc = jnp.where(loc.found, state.v_inc[jnp.where(loc.found, loc.slot, 0)], ABSENT_INC)

    perm, (s_op, s_key, s_init_live, s_init_inc, s_slot, s_found, s_isv) = _sort_by(
        (vkey, phase), op, vkey, init_live, init_inc, loc.slot, loc.found, is_vop
    )
    head = jnp.concatenate([jnp.ones((1,), bool), s_key[1:] != s_key[:-1]])

    # 2-state DFA transition (f(dead), f(live)) per op:
    #   AddVertex  -> const live   (dead: insert/revive; live: fail, stays live)
    #   RemVertex  -> const dead
    #   Contains   -> identity
    is_add = s_op == OP_ADD_VERTEX
    is_rem = s_op == OP_REMOVE_VERTEX
    f0 = jnp.where(is_add, 1, 0).astype(jnp.int32)          # id/rem: 0, add: 1
    f1 = jnp.where(is_rem, 0, 1).astype(jnp.int32)          # id/add: 1, rem: 0
    # head elements become f ∘ const(init): a constant function — this makes
    # plain associative_scan segment-safe (constants absorb everything left).
    init01 = s_init_live.astype(jnp.int32)
    hf = jnp.where(init01 == 1, f1, f0)
    f0 = jnp.where(head, hf, f0)
    f1 = jnp.where(head, hf, f1)

    after0, _ = scan_fnpairs(f0, f1)           # after head-collapse, f0 == f1
    live_after = after0.astype(bool)
    live_before = jnp.where(head, s_init_live, shift_right(live_after, False))

    success = jnp.where(
        is_add,
        ~live_before,
        jnp.where(is_rem, live_before, live_before),  # contains: live_before
    ) & s_isv

    # incarnation: bumps on every successful Add (dead -> live transition)
    revive = (is_add & success).astype(jnp.int32)
    inc_before = s_init_inc + seg_cumsum_exclusive(revive, head)
    inc_after = inc_before + revive

    # group-final state at segment last positions
    last = jnp.concatenate([head[1:], jnp.ones((1,), bool)])

    # --- write-back -------------------------------------------------------
    v_live, v_inc, v_key_col = state.v_live, state.v_inc, state.v_key
    upd = last & s_isv & s_found
    wslot = jnp.where(upd, s_slot, v_key_col.shape[0])
    v_live = v_live.at[wslot].set(live_after, mode="drop")
    v_inc = v_inc.at[wslot].set(inc_after, mode="drop")

    # brand-new keys: insert if the key was ever successfully added (inc >= 0)
    # even when finally dead — the tombstone pins the incarnation so stale
    # edges bound during this batch can never be revived by a later AddVertex.
    need_insert = last & s_isv & ~s_found & (inc_after >= 0)
    v_key_col, new_slots, ins_overflow, rounds = claim_vertex_slots(
        v_key_col, s_key, need_insert
    )
    islot = jnp.where(need_insert & (new_slots >= 0), new_slots, v_key_col.shape[0])
    v_live = v_live.at[islot].set(live_after, mode="drop")
    v_inc = v_inc.at[islot].set(inc_after, mode="drop")

    state = state._replace(v_key=v_key_col, v_live=v_live, v_inc=v_inc)

    # results back to original order
    results = jnp.zeros((n,), bool).at[perm].set(success)

    # transition events for the stabbing wave, in original batch order
    ev_live = jnp.zeros((n,), bool).at[perm].set(live_after)
    ev_inc = jnp.zeros((n,), jnp.int32).at[perm].set(inc_after)

    overflow = loc.overflow | ins_overflow
    n_inserted = jnp.sum(need_insert & (new_slots >= 0)).astype(jnp.int32)
    return state, results, (ev_live, ev_inc), overflow, n_inserted, rounds


# ---------------------------------------------------------------------------
# B. stabbing wave: endpoint (live, inc) at each edge op's phase
# ---------------------------------------------------------------------------

def _stab_scan(state: GraphState, tkeys, tphases, t_set, ev_live, ev_inc, qkeys, qphases):
    """The core stabbing scan: merge vertex-transition events ``(tkeys,
    tphases)`` carrying post-op payloads ``(ev_live, ev_inc)`` with endpoint
    queries ``(qkeys, qphases)``, sort by (key, phase), and answer every
    query with its key's (live, inc) *at its phase* via one head-seeded
    last-set scan.  Inert lanes carry the INT32_MAX key sentinel.  Returns
    ``(q_live, q_inc, overflow)`` aligned with the query arrays.

    This is the paper's Fig. 3 stabbing discipline as a standalone pass: the
    monolithic :func:`apply_batch` feeds it the batch's own endpoint queries,
    and the partitioned pipeline (:mod:`repro.core.sharding`) feeds the owner
    shard's transitions with *remote* shards' endpoint queries — same scan,
    same semantics, so cross-shard answers match the replicated ones.
    """
    nt = tkeys.shape[0]
    nq = qkeys.shape[0]
    ekey = jnp.concatenate([tkeys, qkeys])
    ephase = jnp.concatenate([tphases, qphases])
    is_set = jnp.concatenate([t_set, jnp.zeros((nq,), bool)])

    # every event knows its key's initial table state (for segment heads)
    loc = locate_vertices(state.v_key, ekey, ekey != _INT32_MAX)
    init_live = jnp.where(loc.found, state.v_live[jnp.where(loc.found, loc.slot, 0)], False)
    init_inc = jnp.where(loc.found, state.v_inc[jnp.where(loc.found, loc.slot, 0)], ABSENT_INC)

    pay_live = jnp.concatenate([ev_live, jnp.zeros((nq,), bool)])
    pay_inc = jnp.concatenate([ev_inc, jnp.zeros((nq,), jnp.int32)])

    perm, (s_key, s_set, s_pl, s_pi, s_il, s_ii) = _sort_by(
        (ekey, ephase), ekey, is_set, pay_live, pay_inc, init_live, init_inc
    )
    head = jnp.concatenate([jnp.ones((1,), bool), s_key[1:] != s_key[:-1]])

    # head elements are always "set": a head transition keeps its own payload,
    # a head query seeds the segment with the table's initial state.
    val_live = jnp.where(head & ~s_set, s_il, s_pl)
    val_inc = jnp.where(head & ~s_set, s_ii, s_pi)
    val_set = head | s_set

    (scan_live, scan_inc), _ = scan_last_set((val_live, val_inc), val_set)

    # read back query results in original order
    out_live = jnp.zeros((nt + nq,), bool).at[perm].set(scan_live)
    out_inc = jnp.zeros((nt + nq,), jnp.int32).at[perm].set(scan_inc)
    return out_live[nt:], out_inc[nt:], loc.overflow


def _stabbing_wave(state: GraphState, batch: OpBatch, is_eop, ev_live, ev_inc, is_vop):
    op, u, v, phase = batch.op, batch.u, batch.v, batch.phase
    n = op.shape[0]

    # Event list (3n): vertex transitions + u-queries + v-queries of edge ops
    # (the concat order is load-bearing: the stable lexsort's tie-breaks — and
    # therefore the 1-shard bit-identity — depend on it).
    tkey = jnp.where(is_vop, u, _INT32_MAX)
    qukey = jnp.where(is_eop, u, _INT32_MAX)
    qvkey = jnp.where(is_eop, v, _INT32_MAX)
    qkeys = jnp.concatenate([qukey, qvkey])
    qphases = jnp.concatenate([phase, phase])

    # note: the locate inside _stab_scan re-walks chains after the vertex
    # wave may have inserted keys — init state must reflect the *pre-batch*
    # table (head queries precede all in-batch transitions of their key), so
    # apply_batch passes the pre-wave table into this function.
    q_live, q_inc, overflow = _stab_scan(
        state, tkey, phase, is_vop, ev_live, ev_inc, qkeys, qphases
    )
    u_live, u_inc = q_live[:n], q_inc[:n]
    v_live, v_inc = q_live[n:], q_inc[n:]
    return (u_live, u_inc, v_live, v_inc), overflow


# ---------------------------------------------------------------------------
# C. edge wave
# ---------------------------------------------------------------------------

def _edge_wave(state: GraphState, batch: OpBatch, is_eop, endpoint):
    op, u, v, phase = batch.op, batch.u, batch.v, batch.phase
    n = op.shape[0]
    u_live, u_inc, v_live, v_inc = endpoint

    eku = jnp.where(is_eop, u, _INT32_MAX)
    ekv = jnp.where(is_eop, v, _INT32_MAX)
    loc = locate_edges(state.e_key_u, state.e_key_v, eku, ekv, is_eop)
    safe = jnp.where(loc.found, loc.slot, 0)
    init_live = jnp.where(loc.found, state.e_live[safe], False)
    init_bu = jnp.where(loc.found, state.e_inc_u[safe], ABSENT_INC)
    init_bv = jnp.where(loc.found, state.e_inc_v[safe], ABSENT_INC)

    # sort by (u, v, phase)
    perm, (s_op, s_ku, s_kv, s_ul, s_ui, s_vl, s_vi, s_il, s_ibu, s_ibv,
           s_slot, s_found, s_ise) = _sort_by(
        (eku, ekv, phase), op, eku, ekv, u_live, u_inc, v_live, v_inc,
        init_live, init_bu, init_bv, loc.slot, loc.found, is_eop,
    )
    head = jnp.concatenate(
        [jnp.ones((1,), bool), (s_ku[1:] != s_ku[:-1]) | (s_kv[1:] != s_kv[:-1])]
    )

    eligible = s_ul & s_vl & s_ise
    # epoch id changes at group heads and whenever (eligibility, incs) changes
    prev_elig = shift_right(eligible, False)
    prev_ui = shift_right(s_ui, jnp.int32(-2))
    prev_vi = shift_right(s_vi, jnp.int32(-2))
    epoch_change = head | (eligible != prev_elig) | (
        eligible & ((s_ui != prev_ui) | (s_vi != prev_vi))
    )

    # epoch seed: stored binding is valid iff it matches this epoch exactly
    seed = s_il & (s_ibu == s_ui) & (s_ibv == s_vi) & eligible
    # only the group's first epoch can possibly match the stored binding
    # (incarnations grow), but evaluating at every epoch head is harmless.

    # 1-bit validity DFA: AddE -> const 1, RemE -> const 0, Contains/⊥ -> id
    is_adde = (s_op == OP_ADD_EDGE) & eligible
    is_reme = (s_op == OP_REMOVE_EDGE) & eligible
    f0 = jnp.where(is_adde, 1, 0).astype(jnp.int32)
    f1 = jnp.where(is_reme, 0, 1).astype(jnp.int32)
    seed01 = seed.astype(jnp.int32)
    hf = jnp.where(seed01 == 1, f1, f0)
    f0 = jnp.where(epoch_change, hf, f0)
    f1 = jnp.where(epoch_change, hf, f1)

    after0, _ = scan_fnpairs(f0, f1)
    valid_after = after0.astype(bool)
    valid_before = jnp.where(epoch_change, seed, shift_right(valid_after, False))

    is_cone = s_op == OP_CONTAINS_EDGE
    success = jnp.where(
        is_adde, ~valid_before,
        jnp.where(is_reme, valid_before, eligible & is_cone & valid_before),
    ) & s_ise

    # group-final state
    last = jnp.concatenate([head[1:], jnp.ones((1,), bool)])
    fin_valid = valid_after
    fin_bu = s_ui
    fin_bv = s_vi

    # --- write-back -------------------------------------------------------
    e_live, e_bu, e_bv = state.e_live, state.e_inc_u, state.e_inc_v
    e_ku_col, e_kv_col = state.e_key_u, state.e_key_v
    cap = e_ku_col.shape[0]

    upd = last & s_ise & s_found
    wslot = jnp.where(upd, s_slot, cap)
    e_live = e_live.at[wslot].set(fin_valid, mode="drop")
    e_bu = e_bu.at[wslot].set(fin_bu, mode="drop")
    e_bv = e_bv.at[wslot].set(fin_bv, mode="drop")

    need_insert = last & s_ise & ~s_found & fin_valid
    e_ku_col, e_kv_col, new_slots, ins_overflow, rounds = claim_edge_slots(
        e_ku_col, e_kv_col, s_ku, s_kv, need_insert
    )
    islot = jnp.where(need_insert & (new_slots >= 0), new_slots, cap)
    e_live = e_live.at[islot].set(fin_valid, mode="drop")
    e_bu = e_bu.at[islot].set(fin_bu, mode="drop")
    e_bv = e_bv.at[islot].set(fin_bv, mode="drop")

    state = state._replace(
        e_key_u=e_ku_col, e_key_v=e_kv_col, e_live=e_live, e_inc_u=e_bu, e_inc_v=e_bv
    )
    results = jnp.zeros((n,), bool).at[perm].set(success)
    overflow = loc.overflow | ins_overflow
    n_inserted = jnp.sum(need_insert & (new_slots >= 0)).astype(jnp.int32)
    return state, results, overflow, n_inserted, rounds


# ---------------------------------------------------------------------------
# full pass
# ---------------------------------------------------------------------------

@jax.jit
def apply_batch(state: GraphState, batch: OpBatch) -> ApplyResult:
    # NOTE: no buffer donation — the host wrapper keeps the pre-state alive
    # for transactional growth-and-retry (see WaitFreeGraph.apply).
    """Resolve a whole op batch in phase order; bounded depth (wait-free)."""
    op = batch.op
    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    is_eop = (op == OP_ADD_EDGE) | (op == OP_REMOVE_EDGE) | (op == OP_CONTAINS_EDGE)

    pre_state = state
    state, v_results, (ev_live, ev_inc), v_over, v_ins, v_rounds = _vertex_wave(
        state, batch
    )
    # stabbing wave must read *pre-batch* init states (head queries precede
    # all in-batch transitions of their key), so pass the pre-wave table.
    endpoint, s_over = _stabbing_wave(pre_state, batch, is_eop, ev_live, ev_inc, is_vop)
    state, e_results, e_over, e_ins, e_rounds = _edge_wave(state, batch, is_eop, endpoint)

    success = jnp.where(is_vop, v_results, jnp.where(is_eop, e_results, False))
    ok = ~(v_over | s_over | e_over)

    # stats the waves compute anyway (see types.STAT_*); the obs layer reads
    # them host-side — slots 0-2 (conflict split) are FPSP-only and stay 0
    zero = jnp.int32(0)
    stats = jnp.stack(
        [
            zero,
            zero,
            zero,
            (v_ins + e_ins).astype(jnp.int32),
            zero,
            jnp.sum(is_vop).astype(jnp.int32),
            jnp.sum(is_eop).astype(jnp.int32),
            (v_rounds + e_rounds).astype(jnp.int32),
        ]
    )
    return ApplyResult(state=state, success=success, ok=ok, stats=stats)


# ---------------------------------------------------------------------------
# phase entry points for the partitioned (cross-shard) pipeline
# ---------------------------------------------------------------------------
#
# The sharded graph (repro.core.sharding / WaitFreeGraph n_shards > 1) runs
# the same three waves as apply_batch, but split across shards with a
# host-gathered stab exchange in the middle:
#
#   settle_vertices  — per shard, over its *owned* vertex ops only;
#   answer_stabs     — per endpoint-owner shard, answering remote shards'
#                      (endpoint, phase) queries against its own transitions;
#   settle_edges     — per shard, over its owned edge ops, fed the gathered
#                      endpoint answers.
#
# Each is an independently jitted pass so per-shard sub-batches (different
# bucket sizes per shard) compile once per bucket, exactly like apply_batch.


@jax.jit
def settle_vertices(state: GraphState, batch: OpBatch):
    """Vertex wave as a standalone pass.  Returns ``(state', results,
    ev_live, ev_inc, overflow, stats)`` — the ev arrays are the per-lane
    post-op (live, inc) transition payloads the stabbing wave consumes;
    ``stats`` is ``i32[3]: [n_inserted, claim_rounds, n_vops]`` (the obs
    layer's per-shard vertex-wave counters)."""
    op = batch.op
    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    state, results, (ev_live, ev_inc), overflow, n_ins, rounds = _vertex_wave(
        state, batch
    )
    stats = jnp.stack([n_ins, rounds, jnp.sum(is_vop).astype(jnp.int32)])
    return state, results, ev_live, ev_inc, overflow, stats


@jax.jit
def answer_stabs(
    pre_state: GraphState,
    batch: OpBatch,
    ev_live: jnp.ndarray,
    ev_inc: jnp.ndarray,
    qkeys: jnp.ndarray,
    qphases: jnp.ndarray,
):
    """Answer endpoint (live, inc)-at-phase queries against this shard's
    vertex transitions.

    ``pre_state`` must be the shard's *pre-vertex-wave* table (head queries
    precede every in-batch transition of their key, so their seed is the
    pre-batch state); ``batch``/``ev_live``/``ev_inc`` are the shard's own
    sub-batch and the transition payloads :func:`settle_vertices` returned
    for it.  ``qkeys``/``qphases`` are the gathered queries (INT32_MAX lanes
    are inert padding).  Returns ``(live, inc, overflow)`` per query."""
    op, u = batch.op, batch.u
    is_vop = (op == OP_ADD_VERTEX) | (op == OP_REMOVE_VERTEX) | (op == OP_CONTAINS_VERTEX)
    tkey = jnp.where(is_vop, u, _INT32_MAX)
    return _stab_scan(
        pre_state, tkey, batch.phase, is_vop, ev_live, ev_inc, qkeys, qphases
    )


@jax.jit
def settle_edges(
    state: GraphState,
    batch: OpBatch,
    u_live: jnp.ndarray,
    u_inc: jnp.ndarray,
    v_live: jnp.ndarray,
    v_inc: jnp.ndarray,
):
    """Edge wave as a standalone pass, fed externally gathered endpoint
    answers.  Returns ``(state', results, overflow, stats)`` with ``stats``
    = ``i32[4]: [n_edge_dup, n_inserted, claim_rounds, n_eops]`` (dup is
    FPSP-only and stays 0 here — same layout as the FPSP twin so the
    sharded pipeline unpacks both identically)."""
    op = batch.op
    is_eop = (op == OP_ADD_EDGE) | (op == OP_REMOVE_EDGE) | (op == OP_CONTAINS_EDGE)
    state, results, overflow, n_ins, rounds = _edge_wave(
        state, batch, is_eop, (u_live, u_inc, v_live, v_inc)
    )
    stats = jnp.stack([jnp.int32(0), n_ins, rounds, jnp.sum(is_eop).astype(jnp.int32)])
    return state, results, overflow, stats
