"""musicgen-medium [audio] — decoder-only over 4 EnCodec codebooks; the
EnCodec frontend is a STUB (input_specs supplies token streams with the delay
pattern already applied).  [arXiv:2306.05284; hf]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,          # MHA
    d_head=64,
    d_ff=6144,
    vocab=2048,
    norm="layernorm",
    norm_bias=True,
    act="gelu",
    mlp_bias=True,
    rope=False,             # sinusoidal absolute positions
    n_codebooks=4,
    max_seq=32768,
)
