"""granite-moe-3b-a800m [moe] — 40 experts top-8, narrow per-expert FFN.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,                       # per-expert width
    vocab=49155,
    norm="rmsnorm",
    act="swiglu",
    rope=True,
    moe=MoEConfig(n_experts=40, top_k=8, expert_ff=512),
)
