"""command-r-plus-104b [dense] — GQA, no biases, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=33792,
    vocab=256000,
    norm="layernorm",
    norm_bias=False,
    act="swiglu",
    rope=True,
    tie_embeddings=True,
)
