"""zamba2-1.2b [hybrid] — Mamba-2 backbone with a single shared attention
block applied every 6th layer.  [arXiv:2411.15242; hf]"""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,          # shared block is MHA
    d_head=64,
    d_ff=8192,              # shared block MLP width
    vocab=32000,
    norm="rmsnorm",
    act="swiglu",
    rope=True,
    ssm=SSMConfig(state=64, head_dim=64, conv=4),
    shared_attn_every=6,
)
