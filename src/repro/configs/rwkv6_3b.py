"""rwkv6-3b (Finch) [ssm] — attention-free, data-dependent per-channel decay.
[arXiv:2404.05892; hf]"""

from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    norm="layernorm",
    norm_bias=True,
    act="gelu",                 # unused by rwkv blocks (channel-mix is fixed)
    rope=False,
    ssm=SSMConfig(state=64, head_dim=64, decay_lora=64),
)
