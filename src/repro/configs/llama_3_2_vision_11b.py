"""llama-3.2-vision-11b [vlm] — text backbone with gated cross-attention
image layers every 5th layer; vision frontend is a STUB (input_specs supplies
precomputed patch embeddings projected to d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=128256,
    norm="rmsnorm",
    act="swiglu",
    rope=True,
    rope_theta=500_000.0,
    xattn_every=5,
    n_img_tokens=4096,
)
