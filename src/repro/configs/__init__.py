"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports ``CONFIG`` (the exact public-literature configuration)
and the registry derives the reduced smoke config via
``repro.models.config.reduced_for_smoke``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, reduced_for_smoke

_MODULES = {
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen2-7b": "qwen2_7b",
    "starcoder2-15b": "starcoder2_15b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x7b": "mixtral_8x7b",
    "rwkv6-3b": "rwkv6_3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "zamba2-1.2b": "zamba2_1_2b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_NAMES = tuple(_MODULES)

# input-shape cells shared by the LM family (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return reduced_for_smoke(get_config(name))


def cell_is_runnable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True
