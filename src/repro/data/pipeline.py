"""Deterministic, host-sharded, checkpointable synthetic token pipeline.

Every (host, step) pair derives an independent PRNG stream from
(seed, host_id, step), so:
  * hosts never need to exchange data-pipeline state,
  * restoring a checkpoint at step N reproduces the exact batch sequence
    (the iterator state is just the step counter),
  * elastic resizes re-map shards deterministically: host h of H' hosts
    draws the global batch rows [h*B/H', (h+1)*B/H') from the same
    step-keyed global stream, so the *global* batch is invariant to the
    number of hosts.

The "corpus" is a mixture of Zipfian unigrams and short repeated motifs —
enough structure for loss curves to be meaningfully decreasing, with no
external data dependency (the paper needs no corpus; the LM substrate does).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_codebooks: int = 1
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.5


class SyntheticTokenStream:
    """Stateful iterator; state == step counter (checkpointable)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.step = 0

    # -- checkpoint interface ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    # -- batch generation ------------------------------------------------------
    def _rows(self, step: int, row_lo: int, row_hi: int) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(
            (row_hi - row_lo, cfg.seq_len + 1)
            if cfg.n_codebooks == 1
            else (row_hi - row_lo, cfg.seq_len + 1, cfg.n_codebooks),
            np.int64,
        )
        for i, row in enumerate(range(row_lo, row_hi)):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, row])
            )
            shape = (cfg.seq_len + 1,) if cfg.n_codebooks == 1 else (
                cfg.seq_len + 1, cfg.n_codebooks)
            toks = rng.zipf(cfg.zipf_a, size=shape) % cfg.vocab
            # overlay repeated motifs (learnable local structure)
            if rng.random() < cfg.motif_prob:
                m = rng.integers(0, cfg.vocab, cfg.motif_len)
                reps = (cfg.seq_len + 1) // cfg.motif_len
                motif_stream = np.tile(m, reps + 1)[: cfg.seq_len + 1]
                mask = rng.random(cfg.seq_len + 1) < 0.5
                if cfg.n_codebooks == 1:
                    toks = np.where(mask, motif_stream, toks)
                else:
                    toks = np.where(mask[:, None], motif_stream[:, None], toks)
            out[i] = toks
        return out

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // self.n_hosts
        lo = self.host_id * per_host
        rows = self._rows(self.step, lo, lo + per_host)
        self.step += 1
        tokens = rows[..., :-1] if cfg.n_codebooks == 1 else rows[:, :-1]
        targets = rows[..., 1:] if cfg.n_codebooks == 1 else rows[:, 1:]
        return {
            "tokens": np.ascontiguousarray(tokens, np.int32),
            "targets": np.ascontiguousarray(targets, np.int32),
            "mask": np.ones((per_host, cfg.seq_len), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
