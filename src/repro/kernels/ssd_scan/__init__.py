from .ops import ssd_scan
from .ref import linear_scan_chunked, linear_scan_reference, linear_scan_step

__all__ = [
    "ssd_scan",
    "linear_scan_reference",
    "linear_scan_chunked",
    "linear_scan_step",
]
