"""Pure-jnp oracle for the gated linear-attention / SSD state scan.

Semantics (per batch b, head h), with per-channel decay w_t ∈ (0,1]^K:

    H_t = diag(w_t) · H_{t-1} + k_t ⊗ v_t          (state: K×V matrix)
    y_t = H_tᵀ · q_t                                (readout)

This covers both assigned recurrent families:
  * Mamba-2 / SSD  — scalar decay a_t (broadcast over K),
  * RWKV-6 (Finch) — data-dependent per-channel decay w_t.

``linear_scan_reference`` is the exact sequential recurrence (the oracle).
``linear_scan_chunked`` is the chunked form used by the models on CPU/dry-run
(compact HLO, numerically safe: all exponent differences are ≤ 0).
``linear_scan_step`` is the O(1) decode step for serving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_reference(
    q: jnp.ndarray,  # (B, H, S, K)
    k: jnp.ndarray,  # (B, H, S, K)
    v: jnp.ndarray,  # (B, H, S, V)
    w: jnp.ndarray,  # (B, H, S, K) decay in (0, 1]
    h0: jnp.ndarray | None = None,  # (B, H, K, V)
    *,
    strict: bool = False,
):
    """``strict=False``: y_t = q_t·H_t (SSD/Mamba-2 readout-after-update).
    ``strict=True``:  y_t = q_t·H_{t-1} (RWKV-6 readout-before-update; the
    per-token "bonus" u⊙k_t term is added by the caller)."""
    B, H, S, K = q.shape
    V = v.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(h, xs):
        qt, kt, vt, wt = xs  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        if strict:
            y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), h)
        h = h * wt[..., None].astype(jnp.float32) + (
            kt[..., :, None].astype(jnp.float32) * vt[..., None, :].astype(jnp.float32)
        )
        if not strict:
            y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), h)
        return h, y

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (q, k, v, w))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 2, 0, 3).astype(q.dtype), hT


def linear_scan_step(q, k, v, w, h, *, strict: bool = False):
    """One decode step: q,k,w (B,H,K); v (B,H,V); h (B,H,K,V) -> (y, h')."""
    if strict:
        y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), h)
    h = h * w[..., None].astype(jnp.float32) + (
        k[..., :, None].astype(jnp.float32) * v[..., None, :].astype(jnp.float32)
    )
    if not strict:
        y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), h)
    return y.astype(q.dtype), h


def linear_scan_chunked(
    q: jnp.ndarray,  # (B, H, S, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, H, S, V)
    w: jnp.ndarray,  # (B, H, S, K)
    h0: jnp.ndarray | None = None,
    *,
    chunk: int = 64,
    strict: bool = False,
):
    """Chunked scan: state carried across chunks; within a chunk the
    contribution is computed with only non-positive exponents:

      y_t  = (q_t ⊙ e^{L_t}) · H_in  +  Σ_{s≤t} (q_t · (k_s ⊙ e^{L_t - L_s})) v_s
      H_out = diag(e^{L_C}) H_in + Σ_t (k_t ⊙ e^{L_C - L_t}) ⊗ v_t

    with L_t = Σ_{s≤t} log w_s (within-chunk cumulative, ≤ 0, decreasing) —
    every exponent is ≤ 0, so no 1/decay blow-ups for small decays (the
    failure mode of the naive factorized GLA form).
    """
    B, H, S, K = q.shape
    V = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    if h0 is None:
        h0 = jnp.zeros((B, H, K, V), jnp.float32)

    qc = q.reshape(B, H, n, chunk, K).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(B, H, n, chunk, K).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, H, n, chunk, V).transpose(2, 0, 1, 3, 4)
    wc = w.reshape(B, H, n, chunk, K).transpose(2, 0, 1, 3, 4)

    # strict: s < t (readout-before-update, RWKV-6); else s <= t (SSD)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1 if strict else 0)

    def body(h, xs):
        qt, kt, vt, wt = (x.astype(jnp.float32) for x in xs)  # (B,H,C,K/V)
        logw = jnp.log(jnp.maximum(wt, 1e-30))
        L = jnp.cumsum(logw, axis=2)                                  # (B,H,C,K)
        # strict readout sees H_{t-1}: q-side exponent is the *exclusive* sum
        Lq = (L - logw) if strict else L
        # inter-chunk: q decayed to chunk start reads the carried state
        q_in = qt * jnp.exp(Lq)
        y = jnp.einsum("bhck,bhkv->bhcv", q_in, h)
        # intra-chunk: pairwise decayed scores (exponents ≤ 0 under mask)
        diff = Lq[:, :, :, None, :] - L[:, :, None, :, :]            # (B,H,C,C,K)
        scores = jnp.einsum(
            "bhtk,bhsk,bhtsk->bhts", qt, kt, jnp.exp(jnp.minimum(diff, 0.0))
        )
        scores = jnp.where(mask[None, None], scores, 0.0)
        y = y + jnp.einsum("bhts,bhsv->bhtv", scores, vt)
        # state update
        Lc = L[:, :, -1:, :]                                          # (B,H,1,K)
        k_out = kt * jnp.exp(Lc - L)
        h = h * jnp.exp(Lc[:, :, 0, :, None]) + jnp.einsum(
            "bhck,bhcv->bhkv", k_out, vt
        )
        return h, y

    # remat the chunk body: without it, scan AD stacks the (B,H,C,C,K)
    # pairwise-decay residuals across all chunks (40 GiB/device at rwkv6
    # train_4k); with it, only the (B,H,K,V) carries are stored.
    body = jax.checkpoint(body)
    hT, ys = jax.lax.scan(body, h0, (qc, kc, vc, wc))
    ys = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S, V)
    return ys.astype(q.dtype), hT
