"""Public entry point for the SSD / gated-linear-attention scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def ssd_scan(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    *,
    chunk: int = 64,
    scalar_decay: bool = False,
    strict: bool = False,
    impl: str | None = None,
):
    """Returns y (B,H,S,V). For (y, final_state) use the ref module directly."""
    impl = impl or ("kernel" if jax.default_backend() == "tpu" else "chunked")
    if impl == "kernel":
        return _kernel.ssd_scan(
            q, k, v, w, chunk=chunk, scalar_decay=scalar_decay, strict=strict
        )
    if impl == "kernel_interpret":
        return _kernel.ssd_scan(
            q, k, v, w, chunk=chunk, scalar_decay=scalar_decay, strict=strict,
            interpret=True,
        )
    if impl == "chunked":
        y, _ = _ref.linear_scan_chunked(q, k, v, w, chunk=chunk, strict=strict)
        return y
    if impl == "reference":
        y, _ = _ref.linear_scan_reference(q, k, v, w, strict=strict)
        return y
    raise ValueError(f"unknown impl {impl!r}")
