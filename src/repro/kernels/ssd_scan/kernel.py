"""Pallas TPU chunked SSD / gated-linear-attention scan.

TPU adaptation of the recurrence H_t = diag(w_t)H_{t-1} + k_t⊗v_t:

  * grid = (B, H, S/CHUNK) with the chunk axis sequential; the K×V state
    matrix lives in **VMEM scratch for the whole sequence walk** — HBM
    traffic is exactly one read of (q,k,v,w) and one write of y per token
    (the bandwidth floor), zero state traffic.  This is the core hardware
    adaptation: on GPUs these scans recompute state per block from HBM;
    on TPU the sequential grid + persistent VMEM scratch makes the state
    resident.
  * intra-chunk work is formulated with only non-positive exponents
    (see ref.linear_scan_chunked) so small decays cannot overflow.
  * ``scalar_decay=True`` (Mamba-2): the pairwise decay factors collapse to
    a (C, C) matrix, so the intra-chunk term becomes (q·kᵀ ⊙ decay) @ v —
    two MXU matmuls.  ``False`` (RWKV-6): per-channel decay needs the
    (C, C, K) pairwise tensor — VPU-bound, kept at CHUNK=64 to bound VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    q_ref, k_ref, v_ref, w_ref,   # (1,1,C,K/V) VMEM blocks
    y_ref,                         # (1,1,C,V)
    h_scr,                         # (K,V) f32 persistent state
    *,
    chunk: int,
    scalar_decay: bool,
    strict: bool,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    qt = q_ref[0, 0].astype(jnp.float32)  # (C, K)
    kt = k_ref[0, 0].astype(jnp.float32)
    vt = v_ref[0, 0].astype(jnp.float32)  # (C, V)
    wt = w_ref[0, 0].astype(jnp.float32)  # (C, K)

    logw = jnp.log(jnp.maximum(wt, 1e-30))
    L = jnp.cumsum(logw, axis=0)               # (C, K), ≤0 rows
    # strict readout sees H_{t-1}: q-side exponent is the exclusive cumsum
    Lq = (L - logw) if strict else L

    h = h_scr[...]
    # inter-chunk readout
    q_in = qt * jnp.exp(Lq)
    y = jax.lax.dot_general(
        q_in, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, V)

    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = (s_idx < t_idx) if strict else (s_idx <= t_idx)

    if scalar_decay:
        # decay identical across K: one (C,) log-decay vector suffices
        l1 = Lq[:, 0]                              # (C,)
        ls = L[:, 0]
        dd = jnp.exp(jnp.minimum(l1[:, None] - ls[None, :], 0.0))  # (C, C)
        s = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = jnp.where(mask, s * dd, 0.0)
    else:
        # per-channel decay: pairwise (C, C, K) tensor (VPU)
        diff = jnp.minimum(Lq[:, None, :] - L[None, :, :], 0.0)     # (C,C,K)
        s = jnp.einsum("tk,sk,tsk->ts", qt, kt, jnp.exp(diff))
        s = jnp.where(mask, s, 0.0)

    y = y + jax.lax.dot_general(
        s, vt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update (exponents ≤ 0)
    Lc = L[-1:, :]                                  # (1, K)
    k_out = kt * jnp.exp(Lc - L)                    # (C, K)
    h_scr[...] = h * jnp.exp(Lc[0][:, None]) + jax.lax.dot_general(
        k_out, vt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("chunk", "scalar_decay", "strict", "interpret")
)
def ssd_scan(
    q: jnp.ndarray,  # (B, H, S, K)
    k: jnp.ndarray,
    v: jnp.ndarray,  # (B, H, S, V)
    w: jnp.ndarray,  # (B, H, S, K)
    *,
    chunk: int = 64,
    scalar_decay: bool = False,
    strict: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, K = q.shape
    V = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    kernel = functools.partial(
        _ssd_kernel, chunk=chunk, scalar_decay=scalar_decay, strict=strict
    )
    spec_k = pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0))
    spec_v = pl.BlockSpec((1, 1, chunk, V), lambda b, h, c: (b, h, c, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[spec_k, spec_k, spec_v, spec_k],
        out_specs=spec_v,
        out_shape=jax.ShapeDtypeStruct((B, H, S, V), q.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(q, k, v, w)
