"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships three files:
  * ``kernel.py`` — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU
    target; validated with interpret=True on CPU),
  * ``ops.py``    — jit'd public wrapper with backend dispatch,
  * ``ref.py``    — pure-jnp oracle (also the CPU / dry-run path).

Kernels:
  * ``flash_attention`` — training/prefill attention (causal+SWA+GQA).
  * ``paged_attention`` — decode over wfgraph-managed block tables.
  * ``ssd_scan``        — Mamba-2 / RWKV-6 recurrence, VMEM-resident state.
  * ``hash_probe``      — graph-engine locate (VMEM-resident table).
  * ``frontier``        — BFS frontier expansion (gather + scatter-min).
  * ``compact``         — state-maintenance compaction (prefix-sum stream
    compaction + claim-round quadratic-probe placement).
"""
