"""Public entry point for paged decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def paged_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    block_table: jnp.ndarray,
    seq_lens: jnp.ndarray,
    *,
    sm_scale: float | None = None,
    impl: str | None = None,
) -> jnp.ndarray:
    impl = impl or ("kernel" if jax.default_backend() == "tpu" else "reference")
    if impl == "kernel":
        return _kernel.paged_attention(
            q, k_pages, v_pages, block_table, seq_lens, sm_scale=sm_scale
        )
    if impl == "kernel_interpret":
        return _kernel.paged_attention(
            q, k_pages, v_pages, block_table, seq_lens, sm_scale=sm_scale, interpret=True
        )
    if impl == "reference":
        return _ref.paged_attention_reference(
            q, k_pages, v_pages, block_table, seq_lens, sm_scale=sm_scale
        )
    raise ValueError(f"unknown impl {impl!r}")
