"""Pure-jnp oracle for paged decode attention.

One new token per sequence attends over a paged KV cache addressed through a
block table (vLLM-style, adapted to TPU).  The block tables in the serving
engine are *produced by the wait-free graph engine* (sequence -> page
ownership edges), so this op is where the paper's technique meets the
model's inner loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_reference(
    q: jnp.ndarray,            # (B, Hq, D) — one token per sequence
    k_pages: jnp.ndarray,      # (P, page_size, Hkv, D)
    v_pages: jnp.ndarray,      # (P, page_size, Hkv, D)
    block_table: jnp.ndarray,  # (B, pages_per_seq) int32 page ids
    seq_lens: jnp.ndarray,     # (B,) int32 valid KV length per sequence
    *,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    _, pages_per_seq = block_table.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5

    # gather each sequence's pages into a contiguous view (oracle only —
    # the kernel never materializes this)
    k_seq = k_pages[block_table]  # (B, pages, page_size, Hkv, D)
    v_seq = v_pages[block_table]
    S = pages_per_seq * page_size
    k_seq = k_seq.reshape(B, S, Hkv, D)
    v_seq = v_seq.reshape(B, S, Hkv, D)

    qf = q.reshape(B, Hkv, g, D).astype(jnp.float32) * sm_scale
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_seq.astype(jnp.float32))
    pos = jnp.arange(S)[None, :]  # (1, S)
    ok = pos < seq_lens[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_seq.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
