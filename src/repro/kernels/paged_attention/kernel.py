"""Pallas TPU paged decode attention.

Decode is bandwidth-bound: every step reads the whole live KV cache once.
The TPU-native structure:

  * ``PrefetchScalarGridSpec`` stages the block table and sequence lengths
    into SMEM *before* the grid walk, so the k/v BlockSpec ``index_map`` can
    dereference ``block_table[b, p]`` — the page indirection happens in the
    pipeline's DMA engine (HBM -> VMEM double-buffering), not in the compute
    body.  This is the paper-technique hook: the block table handed to the
    DMA engine is exactly the adjacency state maintained by the wait-free
    graph engine.
  * grid = (B, Hkv, pages_per_seq); the page axis is sequential, carrying
    online-softmax (m, l, acc) in VMEM scratch.
  * pages past ``seq_len`` are skipped with ``pl.when`` — with the engine's
    deterministic page allocation, live pages are contiguous in the table.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetch refs
    block_table_ref, seq_lens_ref,
    # VMEM blocks
    q_ref, k_ref, v_ref,
    o_ref,
    m_scr, l_scr, acc_scr,
    *,
    sm_scale: float,
    page_size: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = seq_lens_ref[b]
    page_start = p * page_size

    @pl.when(page_start < seq_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale   # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (page_size, D)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (page_size, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (group, page_size)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pr = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + pr.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(p == np_ - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def paged_attention(
    q: jnp.ndarray,            # (B, Hq, D)
    k_pages: jnp.ndarray,      # (P, page_size, Hkv, D)
    v_pages: jnp.ndarray,      # (P, page_size, Hkv, D)
    block_table: jnp.ndarray,  # (B, pages_per_seq) int32
    seq_lens: jnp.ndarray,     # (B,) int32
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    _, pages_per_seq = block_table.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5

    # view q as (B, Hkv, group, D) so one grid cell owns one kv head's group
    q4 = q.reshape(B, Hkv, group, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, p, bt, sl: (b, h, 0, 0)),
            # page indirection: the DMA engine chases the graph-engine-owned
            # block table
            pl.BlockSpec(
                (1, page_size, 1, D), lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, page_size, 1, D), lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, p, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )

    kernel = functools.partial(_paged_kernel, sm_scale=sm_scale, page_size=page_size)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, q4, k_pages, v_pages)
    return out.reshape(B, Hq, D)
