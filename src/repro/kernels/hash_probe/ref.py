"""Pure-jnp oracle for batched hash-table probing.

Exactly the semantics of ``repro.core.locate._locate`` specialized to the
vertex table: for each query key, walk the triangular probe chain until the
key or an empty slot is found (bounded by MAX_PROBES).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import hash_vertex, probe_slot
from repro.core.types import EMPTY_KEY, MAX_PROBES


def hash_probe_reference(table_keys: jnp.ndarray, query_keys: jnp.ndarray):
    """Returns (found_slot, insert_slot): i32[n] each, -1 where absent/full."""
    cap = table_keys.shape[0]
    n = query_keys.shape[0]
    home = hash_vertex(query_keys, cap)
    init = (jnp.full((n,), -1, jnp.int32), jnp.full((n,), -1, jnp.int32))

    def body(step, carry):
        found, empty = carry
        pending = (found < 0) & (empty < 0)
        s = probe_slot(home, jnp.int32(step), cap)
        k = table_keys[s]
        found = jnp.where(pending & (k == query_keys), s, found)
        empty = jnp.where(pending & (k == EMPTY_KEY) & (k != query_keys), s, empty)
        return (found, empty)

    return jax.lax.fori_loop(0, MAX_PROBES, body, init)
