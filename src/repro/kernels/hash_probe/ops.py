"""Public entry point for the batched hash probe.

The family follows the repo-wide ``kernel/ops/ref`` contract documented
once in ``docs/KERNELS.md`` (bit-identity between impls, env-var override,
interpret-mode CI parity).  Sharding note: the probe consumes only the
*suffix* bits of the 32-bit key hash (``& (capacity - 1)``); the *prefix*
bits route keys to shards (:mod:`repro.core.sharding`), so this kernel runs
unchanged on a per-shard table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def hash_probe(table_keys: jnp.ndarray, query_keys: jnp.ndarray, *, impl: str | None = None):
    impl = impl or ("kernel" if jax.default_backend() == "tpu" else "reference")
    if impl == "kernel":
        return _kernel.hash_probe(table_keys, query_keys)
    if impl == "kernel_interpret":
        return _kernel.hash_probe(table_keys, query_keys, interpret=True)
    if impl == "reference":
        return _ref.hash_probe_reference(table_keys, query_keys)
    raise ValueError(f"unknown impl {impl!r}")
