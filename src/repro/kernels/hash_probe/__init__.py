from .ops import hash_probe
from .ref import hash_probe_reference

__all__ = ["hash_probe", "hash_probe_reference"]
