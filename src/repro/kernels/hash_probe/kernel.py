"""Pallas TPU batched hash probe — the graph engine's locate hot loop.

Hardware adaptation (DESIGN.md §2): the paper's ``WFLocateVertex`` walks a
sorted linked list — pointer chasing, one dependent load per step.  The TPU
version keeps the *entire key column resident in VMEM* (a 2²⁰-slot table is
4 MiB of int32 — comfortably inside the 16 MiB VMEM of a v5e core) and
probes a whole tile of queries per step with vector gathers.  Probe chains
are bounded by MAX_PROBES (growth escapes longer chains), so the kernel's
inner loop is a fixed-trip fori — wait-free locate, vectorized.

Tables larger than VMEM are sharded by hash prefix across cores (the
serving engine never needs more than ~10⁶ page-ownership entries per core).

grid = (n_query_tiles,); per tile: queries staged to VMEM, MAX_PROBES rounds
of gather + compare, masked select of first hit / first empty.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import EMPTY_KEY, MAX_PROBES


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _probe_kernel(table_ref, query_ref, found_ref, empty_ref, *, capacity: int):
    queries = query_ref[...]
    home = (_mix32(queries) & jnp.uint32(capacity - 1)).astype(jnp.int32)
    n = queries.shape[0]
    found0 = jnp.full((n,), -1, jnp.int32)
    empty0 = jnp.full((n,), -1, jnp.int32)

    def body(step, carry):
        found, empty = carry
        pending = (found < 0) & (empty < 0)
        off = (step * (step + 1)) // 2
        slot = (home + off) & (capacity - 1)
        k = table_ref[slot]  # vectorized VMEM gather
        found = jnp.where(pending & (k == queries), slot, found)
        empty = jnp.where(pending & (k == EMPTY_KEY) & (k != queries), slot, empty)
        return (found, empty)

    found, empty = jax.lax.fori_loop(0, MAX_PROBES, body, (found0, empty0))
    found_ref[...] = found
    empty_ref[...] = empty


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def hash_probe(
    table_keys: jnp.ndarray,  # i32[capacity], power-of-two capacity
    query_keys: jnp.ndarray,  # i32[n]
    *,
    block_q: int = 1024,
    interpret: bool = False,
):
    cap = table_keys.shape[0]
    n = query_keys.shape[0]
    assert cap & (cap - 1) == 0
    block_q = min(block_q, n)
    assert n % block_q == 0, (n, block_q)

    kernel = functools.partial(_probe_kernel, capacity=cap)
    found, empty = pl.pallas_call(
        kernel,
        grid=(n // block_q,),
        in_specs=[
            pl.BlockSpec((cap,), lambda i: (0,)),        # whole table in VMEM
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q,), lambda i: (i,)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(table_keys, query_keys)
    return found, empty
