"""Pure-jnp references for the state-maintenance compaction primitives.

The maintenance subsystem (``repro.core.maintenance``) is built from two
primitives that share one sort + prefix-sum core:

* :func:`masked_compact_reference` — stable stream compaction: keep the
  columns of ``values`` whose ``mask`` lane is set, in order, and push the
  rest off the end.  One ``cumsum`` (the prefix sum) turns the mask into
  scatter positions; the result is order-preserving, so every impl of it is
  bit-identical by construction.

* :func:`probe_place_reference` — vectorized quadratic-probe placement:
  insert a set of distinct pre-hashed keys into an empty power-of-two
  table.  The discipline is *priority-ordered claim rounds*, the same one
  :func:`repro.core.locate._claim_slots` uses for engine inserts: every
  pending lane probes its triangular chain for the first currently-empty
  slot, contended slots go to the lowest lane index (scatter-min), winners
  occupy, losers re-probe.  The lowest pending lane always wins its slot,
  so every round places at least one key and the loop is bounded by the
  lane count — placement is wait-free in the same sense as the engines'
  bounded locate.  The round/claim order is fully deterministic, which is
  what lets the host oracle (``maintenance.rehash_host``), this reference,
  and the Pallas kernel produce bit-identical tables.

Placement is bounded by ``max_probes`` — callers pass ``MAX_PROBES`` so a
placement that the engines' bounded locate could never find again reports
``overflow`` instead (the caller grows the table and retries, exactly like
a failed engine pass).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NO_SLOT = -1  # plain int: jnp constants would be captured consts in Pallas


def _probe_slot(home: jnp.ndarray, step: jnp.ndarray, capacity: int) -> jnp.ndarray:
    """Local replica of ``repro.core.hashing.probe_slot`` (triangular
    probing) — the kernel families stay import-free of ``repro.core`` so
    they can be imported standalone (same pattern as ``hash_probe``'s
    ``_mix32`` copy); ``tests/test_kernels.py`` pins the two against each
    other."""
    off = (step * (step + 1)) // 2
    return (home + off) & (capacity - 1)


def masked_compact_reference(
    values: jnp.ndarray,  # i32[R, N] — R payload rows sharing one mask
    mask: jnp.ndarray,    # bool[N]
    *,
    fill: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(out i32[R, N], count i32[]): ``out[:, :count]`` is ``values[:, mask]``
    in lane order; the tail is ``fill``."""
    n = mask.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = jnp.where(mask, pos, n)  # dropped lanes scatter out of range
    out = jnp.full(values.shape, fill, values.dtype)
    out = out.at[:, idx].set(values, mode="drop")
    return out, jnp.sum(mask).astype(jnp.int32)


def probe_place_rounds(
    home: jnp.ndarray,    # i32[m] — pre-hashed home slots
    active: jnp.ndarray,  # bool[m] — lanes that carry a key to place
    *,
    capacity: int,
    max_probes: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The claim-round loop on values — shared verbatim by the reference and
    the Pallas kernel (which runs it on VMEM-resident blocks), so the two
    are bit-identical by construction.  Returns (slots i32[m], overflow
    bool[]); ``slots[i] == -1`` where inactive or unplaced."""
    m = home.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    int_max = jnp.iinfo(jnp.int32).max

    def first_empty(occ, pending):
        def body(step, cand):
            s = _probe_slot(home, jnp.int32(step), capacity)
            take = pending & (cand < 0) & ~occ[s]
            return jnp.where(take, s, cand)

        return jax.lax.fori_loop(0, max_probes, body, jnp.full((m,), _NO_SLOT, jnp.int32))

    def cond(carry):
        _, _, pending, stuck, rounds = carry
        return jnp.any(pending) & ~stuck & (rounds < m)

    def body(carry):
        occ, slots, pending, _, rounds = carry
        cand = first_empty(occ, pending)
        has = pending & (cand >= 0)
        safe = jnp.where(has, cand, 0)
        claim = jnp.full((capacity,), int_max, jnp.int32)
        claim = claim.at[safe].min(jnp.where(has, idx, int_max))
        winner = has & (claim[safe] == idx)
        occ = occ.at[jnp.where(winner, cand, capacity)].set(True, mode="drop")
        slots = jnp.where(winner, cand, slots)
        pending = pending & ~winner
        # no candidate anywhere => no winner can ever appear again: stop
        return occ, slots, pending, ~jnp.any(has), rounds + 1

    occ0 = jnp.zeros((capacity,), bool)
    slots0 = jnp.full((m,), _NO_SLOT, jnp.int32)
    init = (occ0, slots0, active, jnp.asarray(False), jnp.int32(0))
    _, slots, pending, _, _ = jax.lax.while_loop(cond, body, init)
    return slots, jnp.any(pending)


def probe_place_reference(
    home: jnp.ndarray,
    active: jnp.ndarray,
    *,
    capacity: int,
    max_probes: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pure-jnp placement: see :func:`probe_place_rounds`."""
    return probe_place_rounds(home, active, capacity=capacity, max_probes=max_probes)
