"""Public entry points for the compaction primitives.

Dispatch mirrors ``repro.kernels.frontier``: the Pallas kernel on TPU, the
pure-jnp reference elsewhere.  ``REPRO_COMPACT_IMPL`` overrides the default
(CI's ``kernels-interpret`` job sets it to ``kernel_interpret`` so the
interpreter path is forced on CPU).  All impls are bit-identical; callers
that need a *host* (numpy) oracle use ``repro.core.maintenance`` instead.

The full ``kernel/ops/ref`` contract — and the ``probe_place`` VMEM limit
(single-block occupancy map, ~2**22 slots) that hash-prefix sharding
side-steps by keeping per-shard tables small — is documented once in
``docs/KERNELS.md``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def _resolve(impl: str | None) -> str:
    return (
        impl
        or os.environ.get("REPRO_COMPACT_IMPL")
        or ("kernel" if jax.default_backend() == "tpu" else "reference")
    )


def masked_compact(
    values: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    fill: int,
    impl: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    impl = _resolve(impl)
    if impl == "kernel":
        return _kernel.masked_compact(values, mask, fill=fill)
    if impl == "kernel_interpret":
        return _kernel.masked_compact(values, mask, fill=fill, interpret=True)
    if impl == "reference":
        return _ref.masked_compact_reference(values, mask, fill=fill)
    raise ValueError(f"unknown impl {impl!r}")


def probe_place(
    home: jnp.ndarray,
    active: jnp.ndarray,
    *,
    capacity: int,
    max_probes: int,
    impl: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    impl = _resolve(impl)
    if impl == "kernel":
        return _kernel.probe_place(home, active, capacity=capacity, max_probes=max_probes)
    if impl == "kernel_interpret":
        return _kernel.probe_place(
            home, active, capacity=capacity, max_probes=max_probes, interpret=True
        )
    if impl == "reference":
        return _ref.probe_place_reference(
            home, active, capacity=capacity, max_probes=max_probes
        )
    raise ValueError(f"unknown impl {impl!r}")
