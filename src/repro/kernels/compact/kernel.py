"""Pallas TPU kernels for the state-maintenance compaction primitives.

Hardware adaptation (same playbook as ``hash_probe`` and ``frontier``):

* ``masked_compact`` — stable stream compaction is a prefix sum plus a
  scatter.  The mask/value arrays stream through VMEM in ``block_n``
  chunks along a sequential grid while the full output block stays
  resident; a running offset carried in the ``count`` output turns each
  chunk's local ``cumsum`` into global scatter positions.  Compaction is
  order-preserving, so the chunked result is bit-identical to the one-shot
  jnp reference.

* ``probe_place`` — vectorized quadratic-probe placement.  The occupancy
  bitmap and the claim column live on-chip for the whole round loop (the
  same residency argument as ``hash_probe`` keeping the key column in
  VMEM: a 2²⁰-slot occupancy map is 1 MiB), and each round is one
  vectorized gather (first-empty probe) plus one scatter-min (claim).  The
  round loop itself is :func:`repro.kernels.compact.ref.probe_place_rounds`
  — shared verbatim with the pure-jnp reference, so kernel and reference
  are bit-identical by construction.

The ``interpret=True`` path runs the identical kernels through the Pallas
interpreter; CI forces it on CPU (the ``kernels-interpret`` job).  On-TPU
validation of the compiled path rides the same ROADMAP follow-up as the
frontier kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import probe_place_rounds


def _compact_kernel(values_ref, mask_ref, out_ref, count_ref, *, n_pad: int, fill: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, fill, out_ref.dtype)
        count_ref[...] = jnp.zeros((1,), jnp.int32)

    mask = mask_ref[...]             # bool[block_n]
    vals = values_ref[...]           # i32[R, block_n]
    offset = count_ref[0]            # survivors placed by earlier chunks
    local = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = jnp.where(mask, offset + local, n_pad)   # dropped lanes: out of range
    out_ref[...] = out_ref[...].at[:, idx].set(vals, mode="drop")
    count_ref[...] = count_ref[...] + jnp.sum(mask.astype(jnp.int32))


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("fill", "block_n", "interpret"))
def masked_compact(
    values: jnp.ndarray,  # i32[R, N]
    mask: jnp.ndarray,    # bool[N]
    *,
    fill: int,
    block_n: int = 1024,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(out i32[R, N], count i32[]) — see the reference for the contract."""
    r, n = values.shape
    block_n = min(block_n, max(n, 1))
    n_pad = _round_up(max(n, 1), block_n)
    v = jnp.full((r, n_pad), fill, values.dtype).at[:, :n].set(values)
    m = jnp.zeros((n_pad,), bool).at[:n].set(mask)

    kernel = functools.partial(_compact_kernel, n_pad=n_pad, fill=fill)
    out, count = pl.pallas_call(
        kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((r, block_n), lambda j: (0, j)),
            pl.BlockSpec((block_n,), lambda j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((r, n_pad), lambda j: (0, 0)),  # revisited: global scatter
            pl.BlockSpec((1,), lambda j: (0,)),          # running offset carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n_pad), values.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(v, m)
    return out[:, :n], count[0]


def _place_kernel(home_ref, active_ref, slots_ref, over_ref, *, capacity: int, max_probes: int):
    slots, overflow = probe_place_rounds(
        home_ref[...], active_ref[...], capacity=capacity, max_probes=max_probes
    )
    slots_ref[...] = slots
    over_ref[...] = overflow.reshape(1)


@functools.partial(jax.jit, static_argnames=("capacity", "max_probes", "interpret"))
def probe_place(
    home: jnp.ndarray,    # i32[m]
    active: jnp.ndarray,  # bool[m]
    *,
    capacity: int,
    max_probes: int,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(slots i32[m], overflow bool[]) — see the reference for the contract."""
    m = home.shape[0]
    kernel = functools.partial(_place_kernel, capacity=capacity, max_probes=max_probes)
    slots, over = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.bool_),
        ],
        interpret=interpret,
    )(home, active)
    return slots, over[0]
