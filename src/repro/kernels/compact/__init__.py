from .ops import masked_compact, probe_place
from .ref import masked_compact_reference, probe_place_reference, probe_place_rounds

__all__ = [
    "masked_compact",
    "probe_place",
    "masked_compact_reference",
    "probe_place_reference",
    "probe_place_rounds",
]
