"""Pallas TPU frontier-expansion kernel — the traversal engine's hot loop.

Hardware adaptation (same playbook as ``repro.kernels.hash_probe``): the
CPU lowering of the BFS level step — gather edge sources against the
frontier, scatter-min into edge destinations — is near-serial, and it runs
once per BFS level for every query batch.  Here the boolean frontier tile
and the output row block stay resident in VMEM while the CSR edge arrays
stream through in ``block_e`` chunks:

    grid = (source tiles, edge tiles)

Per (i, j) step: gather the frontier block's values at the edge tile's
source slots (one vectorized VMEM gather), propose ``src`` as parent where
the gather hit, and fold the proposals into the output block with a
scatter-min.  The output block is revisited across the edge-tile axis
(initialised to NBR_INF at j == 0), so the full reduction over all edges
lands without ever leaving VMEM.  Min is associative and commutative, so
the tiled reduction is bit-identical to the pure-jnp reference regardless
of edge order — which is what lets one scatter serve both frontier
discovery (hit iff result < NBR_INF) and the papers' ``GetPath`` parent
pointers (the result *is* the parent slot).

The ``interpret=True`` path runs the identical kernel through the Pallas
interpreter, so CPU CI exercises the same code the TPU compiles (see
``tests/test_frontier_kernel.py`` and the ``kernels-interpret`` CI job).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NBR_INF

_LANE = 128  # TPU lane width: last-dim blocks are padded to multiples of this


def _expand_kernel(frontier_ref, src_ref, dst_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, NBR_INF, jnp.int32)

    frontier = frontier_ref[...]     # bool[block_s, C_pad]
    src = src_ref[...]               # i32[block_e]
    dst = dst_ref[...]               # i32[block_e]
    on_edge = jnp.take(frontier, src, axis=1)           # vectorized VMEM gather
    cand = jnp.where(on_edge, src[None, :], NBR_INF)    # i32[block_s, block_e]
    out_ref[...] = out_ref[...].at[:, dst].min(cand)    # in-VMEM scatter-min


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_s", "block_e", "interpret"))
def frontier_expand(
    frontier: jnp.ndarray,  # bool[S, C]
    src: jnp.ndarray,       # i32[Ce], values in [0, C)
    dst: jnp.ndarray,       # i32[Ce], values in [0, C)
    *,
    block_s: int = 8,
    block_e: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """i32[S, C]: min frontier source slot over in-edges, NBR_INF where none."""
    n_src, c = frontier.shape
    n_edges = src.shape[0]
    block_s = min(block_s, max(n_src, 1))
    block_e = min(block_e, max(n_edges, 1))

    s_pad = _round_up(max(n_src, 1), block_s)
    e_pad = _round_up(max(n_edges, 1), block_e)
    c_pad = _round_up(c, _LANE)
    if c_pad == c and e_pad != n_edges:
        # padded edge lanes park on an all-False padding column so their
        # gather misses; grow one lane block if no padding column exists
        c_pad += _LANE

    f = jnp.zeros((s_pad, c_pad), bool).at[:n_src, :c].set(frontier)
    sp = jnp.full((e_pad,), c_pad - 1, jnp.int32).at[:n_edges].set(src)
    dp = jnp.full((e_pad,), c_pad - 1, jnp.int32).at[:n_edges].set(dst)

    out = pl.pallas_call(
        _expand_kernel,
        grid=(s_pad // block_s, e_pad // block_e),
        in_specs=[
            pl.BlockSpec((block_s, c_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
            pl.BlockSpec((block_e,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_s, c_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, c_pad), jnp.int32),
        interpret=interpret,
    )(f, sp, dp)
    return out[:n_src, :c]
