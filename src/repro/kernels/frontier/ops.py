"""Public entry point for the batched frontier expansion.

Dispatch mirrors ``repro.kernels.hash_probe``: the Pallas kernel on TPU,
the pure-jnp reference elsewhere.  ``REPRO_FRONTIER_IMPL`` overrides the
default (CI's ``kernels-interpret`` job sets it to ``kernel_interpret`` so
the interpreter path is forced on CPU).  The shared ``kernel/ops/ref``
contract and this family's VMEM tiling limits are documented in
``docs/KERNELS.md``.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def frontier_expand(
    frontier: jnp.ndarray,
    src: jnp.ndarray,
    dst: jnp.ndarray,
    *,
    impl: str | None = None,
) -> jnp.ndarray:
    impl = (
        impl
        or os.environ.get("REPRO_FRONTIER_IMPL")
        or ("kernel" if jax.default_backend() == "tpu" else "reference")
    )
    if impl == "kernel":
        return _kernel.frontier_expand(frontier, src, dst)
    if impl == "kernel_interpret":
        return _kernel.frontier_expand(frontier, src, dst, interpret=True)
    if impl == "reference":
        return _ref.frontier_expand_reference(frontier, src, dst)
    raise ValueError(f"unknown impl {impl!r}")
