from .ops import frontier_expand
from .ref import NBR_INF, frontier_expand_reference

__all__ = ["frontier_expand", "frontier_expand_reference", "NBR_INF"]
