"""Pure-jnp reference for one BFS frontier expansion.

One level of the traversal engine's batched BFS is a gather + scatter-min:
every edge lane whose *source* slot is on the frontier proposes its source
slot as the parent of its *destination* slot, and each destination keeps the
minimum proposer.  The scatter-min folds the papers' ``GetPath`` parent
pointer into the same pass that discovers the frontier: a column is newly
reached iff its min proposer is not :data:`NBR_INF`, and that proposer *is*
its BFS parent (deterministic — min is order-independent, so the Pallas
kernel tiling the same reduction matches bit-exactly).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# "no in-frontier neighbor" sentinel: larger than any slot index.
NBR_INF = np.int32(np.iinfo(np.int32).max)


def frontier_expand_reference(
    frontier: jnp.ndarray,  # bool[S, C] — per-source frontier masks
    src: jnp.ndarray,       # i32[Ce] — edge source slots, values in [0, C)
    dst: jnp.ndarray,       # i32[Ce] — edge destination slots, values in [0, C)
) -> jnp.ndarray:
    """i32[S, C]: min frontier source slot over in-edges, NBR_INF where none."""
    cand = jnp.where(frontier[:, src], src[None, :].astype(jnp.int32), NBR_INF)
    out = jnp.full(frontier.shape, NBR_INF, jnp.int32)
    return out.at[:, dst].min(cand)
