"""Pallas TPU flash attention (forward), causal + sliding-window + GQA.

TPU-native tiling:
  * grid = (batch, q_heads, Sq/BLOCK_Q, Sk/BLOCK_K); the KV axis is the
    innermost (sequential) grid dimension, so the online-softmax carries
    (m, l, acc) live in VMEM scratch across KV steps — they never touch HBM.
  * BlockSpecs stage (BLOCK_Q × D) of q and (BLOCK_K × D) of k/v into VMEM
    per step; D and the block sizes are multiples of 128 at production
    shapes, keeping the q·kᵀ and p·v matmuls MXU-aligned.
  * GQA is an index_map: q head h reads kv head h // group_size — no
    jnp.repeat materialization.
  * fully-masked (causal / out-of-window) KV blocks are skipped with
    pl.when — the TPU equivalent of CUDA flash's early-exit, expressed as
    predicated compute on the sequential grid walk.

Validated in interpret mode against ``ref.mha_reference`` (see
tests/test_kernels_flash.py); on real TPUs the same code path compiles to
Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                           # output block
    m_scr, l_scr, acc_scr,           # VMEM scratch carries
    *,
    sm_scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    seq_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level visibility: skip fully-masked KV blocks entirely
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window is not None:
        visible = visible & (k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale   # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, BK)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < seq_k
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window is not None:
            ok = ok & (k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0, (Sq, block_q)
    nq = Sq // block_q
    nk = -(-Sk // block_k)
    pad_k = nk * block_k - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, Hq, nq, nk)

    kernel = functools.partial(
        _flash_fwd_kernel,
        sm_scale=sm_scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        seq_k=Sk,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m carry
            pltpu.VMEM((block_q,), jnp.float32),      # l carry
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
