"""Pure-jnp oracles for flash attention.

``mha_reference`` — naive full-matrix attention, the mathematical ground
truth for kernel sweeps (small shapes only: materializes S×S scores).

``mha_chunked`` — lax.scan over KV blocks with online softmax: linear memory,
compact HLO.  This is the path the models use on CPU and in the 512-device
dry-runs (Pallas-TPU cannot compile on the CPU backend), and it is itself
validated against ``mha_reference``.

Both support: causal masking, sliding windows (Mistral-style), GQA
(num_q_heads a multiple of num_kv_heads), and an optional additive bias-free
cross-attention mode (no causal mask, separate kv length).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int | None):
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return ok


def mha_reference(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * sm_scale
    ok = _mask(jnp.arange(Sq), jnp.arange(Sk), causal, window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(
    q: jnp.ndarray,  # (B, Hq, Sq, D)
    k: jnp.ndarray,  # (B, Hkv, Sk, D)
    v: jnp.ndarray,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    block_k: int = 512,
    block_q: int = 512,
    q_offset: int | None = None,
    seq_spec=None,
) -> jnp.ndarray:
    """Double-chunked online-softmax attention (q-outer × kv-inner scans).

    Memory shape under reverse-mode AD: the q-outer scan has **no carry**
    (each q block is independent) and its body is remat'd, so nothing is
    stacked across blocks; the kv-inner scan's carries are (block_q)-sized.
    A single kv-chunked scan instead stacks full-Sq online-softmax carries
    as AD residuals — measured 30+ GiB/device at 104B train_4k.

    ``q_offset``: absolute position of q[0] (decode: Sq=1 at seq_len-1).
    Defaults to Sk - Sq (right-aligned causal).

    ``seq_spec``: optional ``(dp_axes, model_axis)`` enabling the
    **sequence-parallel attention layout** (§Perf iteration 1): q blocks are
    sharded over the model axis (``block_q`` is always divisible by it —
    head counts like 28/4 are not), KV blocks are replicated over it, and
    every chunk-loop tensor is pinned to that layout.  Without the pins,
    SPMD propagation puts fwd scores head-sharded and bwd score-grads
    seq-sharded and inserts an all-to-all *per (q-chunk, kv-chunk) pair per
    layer* — measured 12.6 s/step of ICI time at qwen2-7b train_4k against
    1.6 s for the once-per-layer boundary reshard this layout costs.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = D ** -0.5
    if q_offset is None:
        q_offset = Sk - Sq

    block_q = min(block_q, Sq)
    while Sq % block_q:
        block_q //= 2
    nq = Sq // block_q
    block_k = min(block_k, Sk)
    nk = -(-Sk // block_k)
    pad = nk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qf = q.astype(jnp.float32) * sm_scale
    qb = qf.reshape(B, Hkv, g, nq, block_q, D).transpose(3, 0, 1, 2, 4, 5)
    kb = k.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nk, block_k, D).transpose(2, 0, 1, 3, 4)

    if seq_spec is not None:
        from jax.sharding import PartitionSpec as P

        dp, mdl = seq_spec

        def _pin_q(t):
            return jax.lax.with_sharding_constraint(
                t, P(None, dp, None, None, mdl, None))

        def _pin_kv(t):
            return jax.lax.with_sharding_constraint(
                t, P(None, dp, None, None, None))

        def _pin_o(t):
            return jax.lax.with_sharding_constraint(
                t, P(dp, None, None, mdl, None))

        qb, kb, vb = _pin_q(qb), _pin_kv(kb), _pin_kv(vb)
    else:
        def _pin_o(t):
            return t

    def q_body(_, xs):
        qi, iq = xs  # (B,Hkv,g,block_q,D), scalar block index
        q_pos = q_offset + iq * block_q + jnp.arange(block_q)

        def kv_body(carry, kv_xs):
            (m, l, acc), blk_idx = carry
            kblk, vblk = kv_xs  # (B, Hkv, block_k, D)
            k_pos = blk_idx * block_k + jnp.arange(block_k)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi, kblk.astype(jnp.float32))
            ok = k_pos[None, :] < Sk  # padding mask
            if causal:
                ok = ok & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            scale = jnp.exp(m - m_new)
            l_new = l * scale + p.sum(axis=-1)
            acc_new = acc * scale[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32)
            )
            return ((m_new, l_new, acc_new), blk_idx + 1), None

        m0 = jnp.full((B, Hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, block_q, D), jnp.float32)
        ((m, l, acc), _), _ = jax.lax.scan(
            kv_body, ((m0, l0, a0), jnp.int32(0)), (kb, vb)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, _pin_o(out.astype(q.dtype))

    idxs = jnp.arange(nq, dtype=jnp.int32)
    _, outs = jax.lax.scan(jax.checkpoint(q_body), None, (qb, idxs))
    # (nq, B, Hkv, g, block_q, D) -> (B, Hq, Sq, D)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq, D)
    return out
