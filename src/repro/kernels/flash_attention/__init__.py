from .ops import attention
from .ref import mha_chunked, mha_reference

__all__ = ["attention", "mha_chunked", "mha_reference"]
