"""Public entry point for flash attention: kernel on TPU, oracle elsewhere.

``attention(...)`` dispatches:
  * on TPU backends — the Pallas kernel (``kernel.flash_attention``);
  * on CPU (tests, dry-runs) — the chunked jnp path (``ref.mha_chunked``),
    whose HLO is compact (lax.scan over KV blocks) and memory-linear, so
    512-device dry-run compiles stay tractable;
  * ``impl=`` overrides for benchmarking ("kernel", "chunked", "reference",
    "kernel_interpret").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def _default_impl() -> str:
    return "kernel" if jax.default_backend() == "tpu" else "chunked"


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    sm_scale: float | None = None,
    impl: str | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    impl = impl or _default_impl()
    if impl == "kernel":
        return _kernel.flash_attention(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_q=block_q, block_k=block_k,
        )
    if impl == "kernel_interpret":
        return _kernel.flash_attention(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale,
            block_q=block_q, block_k=block_k, interpret=True,
        )
    if impl == "chunked":
        return _ref.mha_chunked(
            q, k, v, causal=causal, window=window, sm_scale=sm_scale, block_k=block_k
        )
    if impl == "reference":
        return _ref.mha_reference(q, k, v, causal=causal, window=window, sm_scale=sm_scale)
    raise ValueError(f"unknown impl {impl!r}")
