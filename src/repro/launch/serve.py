"""Serving driver: continuous batching over the wait-free paged KV table.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
      --requests 16 --max-batch 4 --verify-failover

Prints per-request completions, engine throughput, page-table stats, and
(with ``--verify-failover``) replays the deterministic op log into a twin
manager to prove a replacement host reconstructs identical page tables.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify-failover", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.key(args.seed))
    eng = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_len=args.max_len,
        page_size=args.page_size, seed=args.seed,
    )

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        shape = (plen,) if cfg.n_codebooks == 1 else (plen, cfg.n_codebooks)
        eng.submit(Request(
            id=i,
            prompt=rng.integers(0, cfg.vocab, size=shape).astype(np.int32),
            max_new_tokens=args.max_new,
            temperature=args.temperature,
        ))

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.generated) for r in done.values())
    print(f"[serve] {cfg.name}: {len(done)} requests, {total_new} tokens, "
          f"{eng.ticks} ticks, {total_new / dt:.1f} tok/s")
    for rid in sorted(done)[:4]:
        print(f"  req {rid}: {done[rid].generated}")
    print(f"[serve] page ops applied: {sum(len(o[0]) for o in eng.pages.op_log)}"
          f" | free pages {len(eng.pages.free)}/{eng.pages.num_pages}")
    if args.verify_failover:
        eng.failover()
        print("[serve] failover replay: page tables identical ✓")


if __name__ == "__main__":
    main()
