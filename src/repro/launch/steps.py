"""Step builders + ShapeDtypeStruct input specs for every (arch × shape).

``build_step(cfg, kind)`` returns the pure function to be jitted:
  * ``train``   — fwd + bwd + AdamW update (donated opt state) — the real
                  per-step cost including the gradient reduction;
  * ``prefill`` — forward over the full prompt, returns last-token logits;
  * ``decode``  — one new token against a KV/recurrent cache (serve_step).

``input_specs(cfg, shape_name, mesh)`` returns the matching stand-ins
(weak-type-correct, shardable, no allocation), with NamedShardings attached
so ``jax.jit(fn).lower(**specs)`` fixes the distribution.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import SHAPES
from repro.models import LM
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import opt_pspecs

from .shardings import batch_pspecs, cache_pspecs, logical_dp


def build_run(cfg: ArchConfig, *, multi_pod: bool, sp: bool = True,
              run_overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    return {
        "attn_impl": "chunked",
        "sp": sp,
        "remat": True,
        "loss_chunk": 512,
        "dp_axes": logical_dp(multi_pod),
        # §Perf-confirmed defaults (EXPERIMENTS.md): pinned seq-parallel
        # attention layout (-78% ICI at qwen2 train) + single-q-block
        # chunking (8x fewer dK/dV partial reductions).  Baseline numbers
        # are reproducible with run_overrides={"attn_seq_shard": False,
        # "attn_block_q": 512}.
        "attn_seq_shard": True,
        "attn_block_q": 4096,
        **(run_overrides or {}),
    }


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

# per-arch microbatch (gradient-accumulation) factors for train_4k: chosen so
# the per-device live set fits 16 GiB HBM (see EXPERIMENTS.md §Dry-run)
TRAIN_ACCUM = {
    "granite-moe-3b-a800m": 2,
    "mixtral-8x7b": 2,
    # 104B: raw (donation-free) dry-run metric reads 19.9 GiB at accum=8;
    # the production step donates params+opt (TrainRunner) which aliases the
    # 5.7 GiB of optimizer/param args -> ~14.2 GiB effective (fits 16 GiB).
    # accum=16 "fixes" the raw metric but doubles the per-microbatch FSDP
    # gathers (collective_s 541->747 s) — not worth it (EXPERIMENTS §Dry-run).
    "command-r-plus-104b": 8,
    "starcoder2-15b": 2,
    "zamba2-1.2b": 2,           # 15.8 GiB at accum=1 — no headroom
}


def build_train_step(cfg: ArchConfig, *, multi_pod: bool, opt_cfg: AdamWConfig = None,
                     accum: int = None, run_overrides: dict = None):
    model = LM(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    run = build_run(cfg, multi_pod=multi_pod, run_overrides=run_overrides)
    accum = accum or TRAIN_ACCUM.get(cfg.name, 1)

    def loss_fn(p, b):
        return model.loss(p, b, run=run)

    # pin weight gradients to the parameter layout: without this the
    # SP-induced cross-"model" reduction of dW materializes the FULL grad on
    # every device (all-reduce, 2(g-1)/g ring traffic); pinned, XLA emits a
    # reduce-scatter onto the TP shard — exactly half the ICI bytes
    # (§Perf qwen2 iteration 3).
    gspecs = model.pspecs(multi_pod=multi_pod)

    def pin_grads(g):
        if not run.get("sp"):
            return g
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s), g, gspecs
        )

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin_grads(grads)
        else:
            # microbatch accumulation: activations live for one microbatch at
            # a time; gradients accumulate in f32 (ZeRO-sharded, tiny).
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )
            g0 = pin_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))

            def body(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = pin_grads(g)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (loss_sum + l, gsum), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), micro
            )
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step, model, run


def build_prefill_step(cfg: ArchConfig, *, multi_pod: bool, run_overrides: dict = None):
    model = LM(cfg)
    run = {**build_run(cfg, multi_pod=multi_pod, run_overrides=run_overrides),
           "remat": False}

    def prefill_step(params, batch):
        states = (
            model.init_recurrent_states(batch["tokens"].shape[0], cfg.param_dtype)
            if model.block_kind in ("rwkv6", "mamba2")
            else None
        )
        hid, _, new_states = model.hidden_states(
            params, batch["tokens"], memory=batch.get("memory"), run=run,
            states=states,
        )
        logits = model._logits(params, hid[:, -1:])
        return logits

    return prefill_step, model, run


def build_decode_step(cfg: ArchConfig, *, multi_pod: bool, run_overrides: dict = None):
    model = LM(cfg)
    run = {**build_run(cfg, multi_pod=multi_pod, run_overrides=run_overrides),
           "remat": False}

    def decode_step(params, tokens, cache, memory=None):
        return model.decode_step(params, tokens, cache, memory=memory, run=run)

    return decode_step, model, run


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins with shardings)
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, pspec_tree, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        shapes_tree,
        pspec_tree,
    )


def param_specs(cfg: ArchConfig, mesh, *, multi_pod: bool):
    model = LM(cfg)
    return _tree_sds(model.shapes(), model.pspecs(multi_pod=multi_pod), mesh)


def opt_state_specs(cfg: ArchConfig, mesh, *, multi_pod: bool):
    model = LM(cfg)
    pshapes = model.shapes()
    ppspecs = model.pspecs(multi_pod=multi_pod)

    def f32(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32)

    shapes = {
        "m": jax.tree.map(f32, pshapes),
        "v": jax.tree.map(f32, pshapes),
        "master": jax.tree.map(f32, pshapes),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    pspecs = opt_pspecs(ppspecs)
    return _tree_sds(shapes, pspecs, mesh)


def batch_specs(cfg: ArchConfig, shape_name: str, mesh, *, multi_pod: bool):
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    dp = logical_dp(multi_pod)
    specs = batch_pspecs(cfg, B, mesh, multi_pod=multi_pod)

    tok_shape = (B, S) if cfg.n_codebooks == 1 else (B, S, cfg.n_codebooks)
    out = {
        "tokens": _sds(tok_shape, jnp.int32, mesh, specs["tokens"]),
        "targets": _sds(tok_shape, jnp.int32, mesh, specs["tokens"]),
        "mask": _sds((B, S), jnp.float32, mesh, specs["mask"]),
    }
    if cfg.xattn_every:
        out["memory"] = _sds(
            (B, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype, mesh, specs["memory"]
        )
    return out


def cache_specs(cfg: ArchConfig, shape_name: str, mesh, *, multi_pod: bool):
    """Decode-cache stand-ins mirroring LM.decode_init's structure."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    model = LM(cfg)
    shapes = jax.eval_shape(
        functools.partial(model.decode_init, B, S)
    )
    pspecs = cache_pspecs(cfg, shapes, B, mesh, multi_pod=multi_pod)
    return _tree_sds(shapes, pspecs, mesh)


def decode_token_specs(cfg: ArchConfig, shape_name: str, mesh, *, multi_pod: bool):
    sh = SHAPES[shape_name]
    B = sh["global_batch"]
    specs = batch_pspecs(cfg, B, mesh, multi_pod=multi_pod)
    tok_shape = (B, 1) if cfg.n_codebooks == 1 else (B, 1, cfg.n_codebooks)
    return _sds(tok_shape, jnp.int32, mesh, specs["tokens"])


def input_specs(cfg: ArchConfig, shape_name: str, mesh, *, multi_pod: bool):
    """Everything jit.lower needs for the given cell, as kwargs."""
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return {
            "params": param_specs(cfg, mesh, multi_pod=multi_pod),
            "opt_state": opt_state_specs(cfg, mesh, multi_pod=multi_pod),
            "batch": batch_specs(cfg, shape_name, mesh, multi_pod=multi_pod),
        }
    if kind == "prefill":
        return {
            "params": param_specs(cfg, mesh, multi_pod=multi_pod),
            "batch": batch_specs(cfg, shape_name, mesh, multi_pod=multi_pod),
        }
    # decode
    out = {
        "params": param_specs(cfg, mesh, multi_pod=multi_pod),
        "tokens": decode_token_specs(cfg, shape_name, mesh, multi_pod=multi_pod),
        "cache": cache_specs(cfg, shape_name, mesh, multi_pod=multi_pod),
    }
    if cfg.xattn_every:
        sh = SHAPES[shape_name]
        specs = batch_pspecs(cfg, sh["global_batch"], mesh, multi_pod=multi_pod)
        out["memory"] = _sds(
            (sh["global_batch"], cfg.n_img_tokens, cfg.d_model),
            cfg.param_dtype, mesh, specs["memory"],
        )
    return out
