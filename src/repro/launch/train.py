"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt --mesh 1x1

Production behaviours demonstrated at CPU scale (all tested):
  * sharded init / jitted train step with NamedShardings from the same
    policy tables the 512-chip dry-run uses;
  * deterministic host-sharded data pipeline (restores mid-stream);
  * async, atomic, self-validating checkpoints; ``--crash-at N`` aborts
    mid-run (after the async save of step N kicks off) and a re-invocation
    resumes from the latest valid checkpoint — the kill/resume path;
  * elastic resume: ``--mesh`` on restore may differ from the saving run
    (checkpoints are mesh-agnostic);
  * straggler/failover property: any host can recompute any other host's
    data shard for any step (pipeline is (seed, step, row)-keyed).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointStore
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import DataConfig, SyntheticTokenStream
from repro.launch.steps import build_train_step
from repro.models import LM
from repro.optim import AdamWConfig, adamw_init
from repro.optim.adamw import opt_pspecs


def make_mesh(spec: str):
    parts = tuple(int(x) for x in spec.split("x"))
    assert len(parts) == 2, "--mesh DxM"
    n = parts[0] * parts[1]
    assert n <= len(jax.devices()), f"mesh {spec} needs {n} devices"
    return jax.make_mesh(parts, ("data", "model"))


class TrainRunner:
    """Owns params/opt/data/ckpt; restartable at any step."""

    def __init__(self, cfg, mesh, *, ckpt_dir: Optional[str], batch: int,
                 seq: int, accum: int = 1, seed: int = 0,
                 opt_cfg: Optional[AdamWConfig] = None, keep: int = 3):
        self.cfg, self.mesh = cfg, mesh
        self.model = LM(cfg)
        self.store = CheckpointStore(ckpt_dir, keep=keep) if ckpt_dir else None
        self.data = SyntheticTokenStream(
            DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                       seed=seed, n_codebooks=cfg.n_codebooks)
        )
        self.step_fn, _, self.run = build_train_step(
            cfg, multi_pod=False, accum=accum, opt_cfg=opt_cfg
        )
        self.pspecs = self.model.pspecs(multi_pod=False)
        self.step = 0
        self.params = None
        self.opt_state = None
        self._jit = None

    # -- state ------------------------------------------------------------
    def init_or_restore(self):
        if self.store is not None and self.store.latest_step() is not None:
            self.restore(self.store.latest_step())
            return "restored"
        with self.mesh:
            self.params = jax.jit(
                self.model.init,
                out_shardings=jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), self.pspecs
                ),
            )(jax.random.key(0))
            self.opt_state = adamw_init(self.params)
        return "initialized"

    def restore(self, step: int):
        """Mesh-agnostic: ``self.mesh`` may differ from the saving run."""
        like_p = jax.eval_shape(self.model.init, jax.random.key(0))
        like = {"params": like_p, "opt": jax.eval_shape(adamw_init, like_p)}
        sh = {
            "params": jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self.pspecs
            ),
            "opt": jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), opt_pspecs(self.pspecs)
            ),
        }
        tree = self.store.restore(step, like, shardings=sh)
        self.params, self.opt_state = tree["params"], tree["opt"]
        extra = self.store.extra(step)
        self.data.load_state_dict(extra["data"])
        self.step = step

    def save(self, *, sync: bool = False):
        if self.store is None:
            return
        payload = {"params": self.params, "opt": self.opt_state}
        extra = {"data": self.data.state_dict(), "step": self.step}
        if sync:
            self.store.save(self.step, payload, extra=extra)
        else:
            self.store.save_async(self.step, payload, extra=extra)

    # -- loop ------------------------------------------------------------
    def train(self, steps: int, *, log_every: int = 10, save_every: int = 50,
              crash_at: Optional[int] = None, log=print):
        if self.params is None:
            self.init_or_restore()
        mesh = self.mesh
        if self._jit is None:
            self._jit = jax.jit(self.step_fn, donate_argnums=(0, 1))
        losses = []
        with mesh:
            t0 = time.time()
            while self.step < steps:
                host_batch = self.data.next_batch()
                batch = {
                    k: jax.device_put(
                        v,
                        NamedSharding(
                            mesh, P("data", *([None] * (v.ndim - 1)))
                        ),
                    )
                    for k, v in host_batch.items()
                }
                self.params, self.opt_state, metrics = self._jit(
                    self.params, self.opt_state, batch
                )
                self.step += 1
                if self.step % log_every == 0 or self.step == steps:
                    loss = float(metrics["loss"])
                    losses.append((self.step, loss))
                    dt = time.time() - t0
                    log(f"step {self.step:5d} loss {loss:.4f} "
                        f"({dt / log_every:.2f}s/step)")
                    t0 = time.time()
                if save_every and self.step % save_every == 0:
                    self.save()
                if crash_at is not None and self.step >= crash_at:
                    # simulated node failure: the async save may be mid-write;
                    # the atomic-rename contract means restore never sees it
                    # half-written.
                    raise SystemExit(42)
        if self.store is not None:
            self.save(sync=True)
            self.store.wait()
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_mesh(args.mesh)
    runner = TrainRunner(cfg, mesh, ckpt_dir=args.ckpt_dir, batch=args.batch,
                         seq=args.seq, accum=args.accum, seed=args.seed)
    print(f"[train] {cfg.name} ({'smoke' if args.smoke else 'FULL'}) "
          f"mesh={args.mesh} -> {runner.init_or_restore()} @ step {runner.step}")
    runner.train(args.steps, log_every=args.log_every,
                 save_every=args.save_every, crash_at=args.crash_at)
    print(f"[train] done @ step {runner.step}")


if __name__ == "__main__":
    main()
