"""Execution-weighted cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body **once** (verified
empirically: a trip-count-8 scan of a matmul reports 1 matmul's FLOPs), so
raw numbers undercount any scanned program — which is every cell here, since
we scan over layers, gradient-accumulation microbatches, KV chunks and loss
chunks.  This module re-derives totals from the compiled module text:

  1. parse computations and ops (two passes: symbol table of op -> result
     type, then structure);
  2. walk the call graph from ENTRY with an execution multiplier —
     ``while`` bodies/conds multiply by ``backend_config known_trip_count``
     (XLA records it for counted loops; fallback: the constant compared
     against in the condition computation), ``fusion``/``call`` descend at
     ×1, ``conditional`` takes the max across branches;
  3. model per-op cost:
       * flops — ``dot``: 2 × |result| × K (K = product of lhs contracting
         dims, lhs shape resolved through the symbol table); ``reduce`` /
         elementwise arithmetic: |operand| or |result|; ``rng``/transcendental
         counted ×1 like XLA does;
       * bytes — per *kernel* (top-level op in a computation): sum of operand
         result-sizes + own result size; fusions count their boundary
         operands/result only (fusion-aware HBM-traffic proxy); plumbing ops
         (tuple/gte/bitcast/parameter/constant/while/conditional) are free;
       * collectives — result bytes by kind (all-gather counts gathered
         bytes, reduce-scatter counts scattered bytes), with replica-group
         size recorded so the roofline can model ring traffic per link.

All counts are execution-weighted (multiplied through enclosing loops).
Validated against exactly-known programs in tests/test_hloparse.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """Split "%name = <type> opcode(..." into (name, type_str, opcode).

    Tuple types embed ``/*index=N*/`` comments and layout braces, so the
    type is extracted by paren matching, not regex.
    """
    m = _NAME_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        type_str, tail = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp:]
    mo = _OPCODE_RE.match(tail)
    if mo is None:
        return None
    return name, type_str, mo.group(1)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_BRANCH_RE = re.compile(
    r"true_computation=%?([\w.\-]+),\s*false_computation=%?([\w.\-]+)"
)
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)"?')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_REPLICA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPLICA_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_INT_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_META_NAME_RE = re.compile(r'op_name="([^"]+)"')

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "logistic", "sign", "floor", "ceil", "round-nearest-even", "atan2",
    "cosine", "sine", "expm1", "log-plus-one", "remainder", "select",
    "clamp", "compare", "and", "or", "xor", "not", "shift-left",
    "shift-right-logical", "shift-right-arithmetic",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "reshape", "custom-call", "opt-barrier", "domain",
    "get-dimension-size", "add-dependency",
}


def shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (sums across tuple elements)."""
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_array_dims(type_str: str) -> List[int]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str

    @property
    def result_bytes(self) -> int:
        return shape_bytes(self.type_str)

    @property
    def result_elems(self) -> int:
        return shape_elems(self.type_str)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (kind, bytes, group_size, multiplier, op_name) per static site
    collective_sites: List[Tuple[str, int, int, float, str]] = dataclasses.field(
        default_factory=list
    )

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult
        for kind, b, g, m, name in other.collective_sites:
            self.collective_sites.append((kind, b, g, m * mult, name))


class HloModule:
    def __init__(self, computations: Dict[str, Computation], entry: str):
        self.computations = computations
        self.entry = entry
        self._symbols: Dict[str, str] = {}  # op name -> result type str
        for comp in computations.values():
            for op in comp.ops:
                self._symbols[op.name] = op.type_str

    def result_type(self, op_name: str) -> str:
        return self._symbols.get(op_name, "")


def parse_module(text: str) -> HloModule:
    computations: Dict[str, Computation] = {}
    entry = ""
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_RE.match(line.strip())
            if m and "=" not in line.split("(", 1)[0]:
                current = Computation(
                    name=m.group(2), ops=[], is_entry=bool(m.group(1))
                )
            continue
        if line.strip() == "}":
            computations[current.name] = current
            if current.is_entry:
                entry = current.name
            current = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, type_str, opcode = parsed
            current.ops.append(
                Op(name=name, type_str=type_str, opcode=opcode, line=line)
            )
    if not entry and computations:
        entry = list(computations)[-1]
    return HloModule(computations, entry)


def _trip_count(module: HloModule, op: Op) -> int:
    m = _TRIP_RE.search(op.line)
    if m:
        return int(m.group(1))
    # fallback: the constant in the condition computation's compare
    mcb = _COND_BODY_RE.search(op.line)
    if mcb:
        cond = module.computations.get(mcb.group(1))
        if cond is not None:
            consts = []
            for o in cond.ops:
                mc = _CONST_INT_RE.search(o.line)
                if mc:
                    consts.append(int(mc.group(1)))
            if consts:
                return max(consts)
    return 1


def _group_size(line: str) -> int:
    m = _REPLICA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _dot_flops(module: HloModule, op: Op) -> float:
    operands = _operand_names(op)
    if not operands:
        return 0.0
    lhs_type = module.result_type(operands[0])
    lhs_dims = _first_array_dims(lhs_type)
    mc = _LHS_CONTRACT_RE.search(op.line)
    k = 1
    if mc and lhs_dims:
        for d in mc.group(1).split(","):
            if d:
                idx = int(d)
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * op.result_elems * k


def _operand_names(op: Op) -> List[str]:
    # operands are inside the top-level parens after the opcode
    start = op.line.find(op.opcode + "(")
    if start < 0:
        return []
    s = op.line[start + len(op.opcode) + 1:]
    depth = 1
    out = []
    buf = []
    for ch in s:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return _OPERANDS_RE.findall("".join(buf))


def _op_cost(module: HloModule, op: Op, comp_costs: Dict[str, Costs]) -> Costs:
    c = Costs()
    opcode = op.opcode

    if opcode == "fusion":
        m = _CALLS_RE.search(op.line)
        if m and m.group(1) in comp_costs:
            inner = comp_costs[m.group(1)]
            c.flops += inner.flops
            c.transcendentals += inner.transcendentals
            # collectives cannot live inside fusions; bytes at the boundary:
        c.bytes += op.result_bytes
        for o in _operand_names(op):
            c.bytes += shape_bytes(module.result_type(o))
        return c

    if opcode == "while":
        mcb = _COND_BODY_RE.search(op.line)
        trips = _trip_count(module, op)
        if mcb:
            body = comp_costs.get(mcb.group(2))
            cond = comp_costs.get(mcb.group(1))
            if body:
                c.add(body, trips)
            if cond:
                c.add(cond, trips)
        return c

    if opcode == "conditional":
        names = []
        m = _BRANCHES_RE.search(op.line)
        if m:
            names = [x.strip().lstrip("%") for x in m.group(1).split(",")]
        else:
            m = _TF_BRANCH_RE.search(op.line)
            if m:
                names = [m.group(1), m.group(2)]
        best: Optional[Costs] = None
        for n in names:
            cc = comp_costs.get(n)
            if cc is not None and (best is None or cc.flops > best.flops):
                best = cc
        if best is not None:
            c.add(best, 1.0)
        return c

    if opcode == "call":
        m = _TO_APPLY_RE.search(op.line)
        if m and m.group(1) in comp_costs:
            c.add(comp_costs[m.group(1)], 1.0)
        return c

    base_kind = opcode[:-6] if opcode.endswith("-start") else opcode
    if base_kind in COLLECTIVE_KINDS:
        b = op.result_bytes
        if opcode.endswith("-done"):
            return c  # counted at -start
        g = _group_size(op.line)
        mname = _META_NAME_RE.search(op.line)
        c.collective_bytes[base_kind] = c.collective_bytes.get(base_kind, 0.0) + b
        c.collective_counts[base_kind] = c.collective_counts.get(base_kind, 0.0) + 1
        c.collective_sites.append(
            (base_kind, b, g, 1.0, mname.group(1)[-120:] if mname else "?")
        )
        c.bytes += b
        for o in _operand_names(op):
            c.bytes += shape_bytes(module.result_type(o))
        return c

    if opcode in _FREE or opcode.endswith("-done"):
        return c

    # materializing kernel: bytes = operands + result
    c.bytes += op.result_bytes
    for o in _operand_names(op):
        c.bytes += shape_bytes(module.result_type(o))

    if opcode == "dot":
        c.flops += _dot_flops(module, op)
    elif opcode == "convolution":
        # rare here; approximate as dot over the first operand
        c.flops += _dot_flops(module, op)
    elif opcode in ("reduce", "reduce-window"):
        ops_ = _operand_names(op)
        if ops_:
            c.flops += shape_elems(module.result_type(ops_[0]))
    elif opcode in _ELEMENTWISE:
        c.flops += op.result_elems
        if opcode in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                      "logistic", "cosine", "sine", "expm1", "log-plus-one"):
            c.transcendentals += op.result_elems
    # everything else (copy, slice, dus, gather, scatter, iota, transpose,
    # broadcast, convert, pad, concatenate, sort, rng, ...) is bytes-only.
    return c


def module_costs(text: str) -> Costs:
    """Execution-weighted totals for one compiled module (per device)."""
    module = parse_module(text)
    comp_costs: Dict[str, Costs] = {}

    # Resolve in dependency order: iterate until fixed point (call graph is a
    # DAG; plain iteration converges in #computations passes, but memoized
    # recursion is cheaper).
    def cost_of(name: str, stack=()) -> Costs:
        if name in comp_costs:
            return comp_costs[name]
        if name in stack:  # defensive: cycles cannot happen in valid HLO
            return Costs()
        comp = module.computations.get(name)
        if comp is None:
            return Costs()
        total = Costs()
        for op in comp.ops:
            for attr_re in (_CALLS_RE, _TO_APPLY_RE):
                m = attr_re.search(op.line)
                if m:
                    cost_of(m.group(1), stack + (name,))
            m = _COND_BODY_RE.search(op.line)
            if m:
                cost_of(m.group(1), stack + (name,))
                cost_of(m.group(2), stack + (name,))
            m = _BRANCHES_RE.search(op.line)
            if m:
                for n in m.group(1).split(","):
                    cost_of(n.strip().lstrip("%"), stack + (name,))
            m = _TF_BRANCH_RE.search(op.line)
            if m:
                cost_of(m.group(1), stack + (name,))
                cost_of(m.group(2), stack + (name,))
            total.add(_op_cost(module, op, comp_costs))
        comp_costs[name] = total
        return total

    # reduction helper computations (to_apply of reduce/all-reduce) would be
    # double counted if we folded them into their callers; we don't — only
    # call/while/fusion/conditional descend.  Their own cost is negligible.
    return cost_of(module.entry)


def summarize(text: str) -> Dict:
    c = module_costs(text)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_bytes": dict(c.collective_bytes),
        "collective_counts": dict(c.collective_counts),
        "collective_sites": [
            {"kind": k, "bytes": b, "group": g, "mult": m, "op": name}
            for k, b, g, m, name in sorted(
                c.collective_sites, key=lambda s: -s[1] * s[3]
            )[:64]
        ],
    }
