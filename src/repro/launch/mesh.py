"""Production mesh definition.

Defined as a FUNCTION (not a module-level constant) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else (tests, benches) must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over real local devices (CPU tests / examples)."""
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
