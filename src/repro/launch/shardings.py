"""Sharding policy: how batches and decode caches map onto the mesh.

Rules (with divisibility fallbacks so every assigned arch × shape lowers):
  * batch dim -> data axes when divisible, else replicated (long_500k, B=1);
  * decode KV caches: batch -> data, cache T axis -> "model"
    (flash-decoding stripes) when divisible;
  * recurrent states: batch -> data, then the first of
    (heads, K, V) divisible by the model axis -> "model";
  * image memory: batch -> data, token axis -> "model".
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig


def logical_dp(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _axis_size(mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def _maybe(dim_size: int, names, mesh):
    """names if divisible else None."""
    return names if dim_size % _axis_size(mesh, names) == 0 else None


def batch_pspecs(cfg: ArchConfig, B: int, mesh, *, multi_pod: bool):
    dp = logical_dp(multi_pod)
    bspec = _maybe(B, dp, mesh)
    return {
        "tokens": P(bspec, None) if cfg.n_codebooks == 1 else P(bspec, None, None),
        "mask": P(bspec, None),
        "memory": P(bspec, _maybe(cfg.n_img_tokens, "model", mesh), None),
    }


def cache_pspecs(cfg: ArchConfig, cache_shapes, B: int, mesh, *, multi_pod: bool):
    """PartitionSpec tree matching LM.decode_init's structure."""
    dp = logical_dp(multi_pod)
    bs = _maybe(B, dp, mesh)

    def kv_spec(shape):
        # (L, B, Hkv, T, Dh): stripe T over model (flash-decoding)
        L, B_, H, T, Dh = shape
        return P(None, bs, None, _maybe(T, "model", mesh), None)

    def state_spec(shape):
        # recurrent: (L, B, ...) — find a trailing dim for "model"
        spec = [None, bs] + [None] * (len(shape) - 2)
        for i in range(2, len(shape)):
            if shape[i] % _axis_size(mesh, "model") == 0 and shape[i] >= _axis_size(mesh, "model"):
                spec[i] = "model"
                break
        return P(*spec)

    def assign(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if keys and keys[-1] == "len":
            return P()
        if "kv" in keys or "shared_kv" in keys or "xkv" in keys:
            return kv_spec(leaf.shape)
        if "states" in keys:
            return state_spec(leaf.shape)
        return P()

    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)
    leaves = [assign(path, leaf) for path, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)
