"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Proves the distribution config is coherent without hardware: compile must
succeed, ``memory_analysis()`` must fit the 16 GiB/chip HBM budget, and
``cost_analysis()`` + the HLO collective sum feed §Roofline.
"""

# The VERY FIRST lines, before ANY other import (jax locks device count on
# first init): give the host platform 512 placeholder devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.launch import hloparse  # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

# ---------------------------------------------------------------------------
# collective-bytes extraction (cost_analysis has no collective term)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op, by kind.

    The result type (right after ``=``) counts gathered bytes for
    all-gather and scattered bytes for reduce-scatter — a consistent
    per-device traffic proxy.  NOTE: ops inside while/scan bodies appear
    once in the HLO; execution counts are restored analytically by the
    roofline calculator (benchmarks/roofline.py), which knows each scan's
    trip count.
    """
    out = {}
    count = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None or "=" not in line:
            continue
        kind = m.group(1)
        if m.group(0).endswith("-done"):
            continue  # avoid double count of async start/done pairs
        rhs = line.split("=", 1)[1]
        sm = _SHAPE_RE.search(rhs)
        if sm is None:
            continue
        b = _shape_bytes(sm.group(0))
        # tuple results (e.g. fused all-gather of several operands): sum all
        # shapes before the op name token
        op_pos = rhs.find(m.group(0))
        b = _shape_bytes(rhs[:op_pos]) if op_pos > 0 else b
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    return out, count


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True,
             hlo_dir: str = None, step_overrides: dict = None):
    cfg = get_config(arch)
    if not cell_is_runnable(cfg, shape):
        return {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "status": "skipped",
            "reason": "long_500k requires sub-quadratic attention (DESIGN.md §5)",
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape]["kind"]
    t0 = time.time()

    step_overrides = step_overrides or {}
    with jax.default_device(jax.devices("cpu")[0]):
        if kind == "train":
            fn, model, run = S.build_train_step(cfg, multi_pod=multi_pod,
                                                **step_overrides)
        elif kind == "prefill":
            fn, model, run = S.build_prefill_step(cfg, multi_pod=multi_pod,
                                                  **step_overrides)
        else:
            fn, model, run = S.build_decode_step(cfg, multi_pod=multi_pod,
                                                 **step_overrides)

        specs = S.input_specs(cfg, shape, mesh, multi_pod=multi_pod)

        with mesh:
            # NOTE donation was tried here (params/opt for train, cache for
            # decode) to mirror the real loop; the CPU backend's buffer
            # assignment got *worse* (+3.7 GiB at 104B train), so the
            # dry-run keeps the donation-free program and the budget table
            # documents it as the conservative bound.
            lowered = jax.jit(fn).lower(**specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        coll, coll_n = collective_bytes(hlo_text)
        # execution-weighted (while bodies × trip count) — see hloparse
        exec_sum = hloparse.summarize(hlo_text)
        if hlo_dir is not None:
            os.makedirs(hlo_dir, exist_ok=True)
            tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
            with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
                f.write(hlo_text)

    n_dev = 512 if multi_pod else 256
    result = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_counts": coll_n,
        # while-body-once undercount corrected (tests/test_hloparse.py):
        "exec": exec_sum,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
    }
    if verbose:
        per_dev_gib = (
            result["memory"]["argument_bytes"]
            + result["memory"]["temp_bytes"]
        ) / 2**30
        print(
            f"[{arch} × {shape} × {'2pods' if multi_pod else '1pod'}] OK "
            f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
            f"flops {result['flops']:.3e} bytes {result['bytes_accessed']:.3e} | "
            f"coll {sum(coll.values()):.3e}B | mem/dev {per_dev_gib:.2f} GiB"
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-out", default="results/hlo")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[{tag}] cached")
                continue
            try:
                result = run_cell(arch, shape, multi_pod=multi_pod,
                                  hlo_dir=args.hlo_out)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                result = {
                    "arch": arch, "shape": shape, "multi_pod": multi_pod,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                }
                failures += 1
            with open(path, "w") as f:
                json.dump(result, f, indent=2)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
