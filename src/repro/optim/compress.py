"""Int8 error-feedback gradient compression for the cross-pod reduction.

At 2×16×16 the pod axis crosses the slow inter-pod links, and the gradient
all-reduce is the only traffic there (data parallelism between pods). This
module provides the standard production trick: quantize per-tensor to int8
around a shared scale, sum in int32 (exact — no quantization of the
*reduction*), dequantize, and carry the quantization residual forward
(error feedback), which restores convergence to the uncompressed optimum.

Usage shape (see tests/test_compress.py for the multi-device form):

    def per_pod_step(params, opt, batch, ef):
        loss, grads = value_and_grad(loss_fn)(params, batch)   # per-pod grads
        grads, ef = compressed_psum(grads, ef, axis="pod")     # 4x fewer bytes
        ...

    shard_map(per_pod_step, mesh,
              in_specs=(..., P("pod")), out_specs=...,
              # data/model stay automatic; only the pod reduction is manual
              auto=frozenset({"data", "model"}))

Traffic: f32 all-reduce moves 2(g-1)/g × 4 B/param per link; int8 moves
2(g-1)/g × 1 B/param (+8 B/tensor for the scale) — **4× compression** of
the inter-pod term. The reduction itself is exact in int32, so determinism
across replicas is preserved (same inputs → same quantized sum everywhere).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
_Q = 127.0


def quantize(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric int8 quantization around a (shared) per-tensor scale."""
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -_Q, _Q)
    return q.astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(F32) * scale


def ef_init(tree):
    """Zero error-feedback residuals shaped like the gradient tree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), tree)


def compressed_psum(
    grads,
    ef,
    *,
    axis: str,
) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis`` (inside shard_map).

    Per tensor: add the carried residual, agree on a shared scale
    (max-abs psum-maxed across the axis so every member quantizes
    identically), quantize, **sum exactly in int32**, dequantize by
    1/group_size (mean), and keep the local quantization error as the next
    step's residual.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        x = g.astype(F32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis)
        scale = jnp.maximum(amax, 1e-12) / _Q
        q = quantize(x, scale)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = dequantize(total, scale) / n
        # residual: what this member failed to contribute this round
        new_e = x - dequantize(q, scale)
        return mean.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def compression_ratio(tree) -> float:
    """Bytes(f32 AR) / bytes(int8 AR + scales) for the given tree."""
    f32_bytes = sum(g.size * 4 for g in jax.tree.leaves(tree))
    int8_bytes = sum(g.size * 1 + 8 for g in jax.tree.leaves(tree))
    return f32_bytes / int8_bytes
