"""AdamW + cosine schedule + global-norm clipping (pure pytrees).

ZeRO sharding falls out of the parameter partition specs: optimizer moments
mirror the parameter tree, so FSDP-sharded params get FSDP-sharded moments
for free (``jax.tree.map`` of the same NamedShardings).  Distributed tricks:

  * ``grad_dtype="bfloat16"`` casts gradients before the cross-replica
    reduction (2x collective-bytes compression; moments stay f32);
  * master weights: when params are bf16, an f32 master copy lives in the
    optimizer state and the bf16 params are re-derived each step (standard
    mixed-precision training).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_dtype: Optional[str] = "bfloat16"   # gradient all-reduce compression
    master_f32: bool = True


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def adamw_init(params):
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, F32)

    return {
        "m": jax.tree.map(zeros_like_f32, params),
        "v": jax.tree.map(zeros_like_f32, params),
        # copy=True: f32 params would otherwise *alias* their master copy and
        # break buffer donation (donate(a), donate(a)) in the train step
        "master": jax.tree.map(lambda p: jnp.array(p, dtype=F32, copy=True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    if cfg.grad_dtype == "bfloat16":
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    grads = jax.tree.map(lambda g: g.astype(F32), grads)

    # global-norm clip
    gsq = sum(jnp.sum(g * g) for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)

    count = state["count"] + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(F32)
    b2c = 1.0 - cfg.b2 ** count.astype(F32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], grads)

    def upd(master, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master)

    new_master = jax.tree.map(upd, state["master"], new_m, new_v)
    new_params = jax.tree.map(
        lambda p, w: w.astype(p.dtype), params, new_master
    )
    new_state = {"m": new_m, "v": new_v, "master": new_master, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def opt_pspecs(param_pspecs):
    """Optimizer-state partition specs mirror the parameter specs (ZeRO)."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_pspecs,
        "v": param_pspecs,
        "master": param_pspecs,
        "count": P(),
    }
