"""Residual blocks for every assigned family.

Block kinds:
  * ``attn``   — pre-norm attention + MLP (dense transformers, shared block
                 of zamba2, musicgen backbone).
  * ``moe``    — pre-norm attention + MoE FFN (mixtral, granite).
  * ``xattn``  — tanh-gated cross-attention to image tokens (llama-3.2-v).
  * ``rwkv6``  — Finch time-mix (data-dependent per-channel decay, strict
                 readout + bonus) + channel-mix.
  * ``mamba2`` — SSD block (conv + scalar-decay scan + gated norm).

Each kind provides ``*_meta(cfg)`` / ``*_apply(params, cfg, x, ...)`` and a
decode-state initializer.  Decode states are pytrees of per-layer tensors so
the full-model decode can lax.scan over stacked layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ref import (
    linear_scan_chunked,
    linear_scan_step,
)

from .config import ArchConfig
from .layers import (
    attn_apply,
    attn_meta,
    mlp_apply,
    mlp_meta,
    moe_apply,
    moe_apply_shardmap,
    moe_meta,
    norm_apply,
    norm_meta,
)
from .module import ParamMeta

F32 = jnp.float32


def _pick_chunk(S: int, target: int = 64) -> int:
    """Largest power-of-two chunk ≤ target that divides S."""
    c = 1
    while c * 2 <= min(target, S) and S % (c * 2) == 0:
        c *= 2
    return c


# ---------------------------------------------------------------------------
# attention (+MLP / +MoE) block
# ---------------------------------------------------------------------------

def attn_block_meta(cfg: ArchConfig, *, moe: bool = False):
    return {
        "ln1": norm_meta(cfg),
        "attn": attn_meta(cfg),
        "ln2": norm_meta(cfg),
        "ffn": moe_meta(cfg) if moe else mlp_meta(cfg),
    }


def attn_block_apply(p, cfg: ArchConfig, x, *, moe=False, positions=None,
                     kv_cache=None, attn_impl="chunked",
                     dp_axes=("data",), shard=False, seq_spec=None,
                     block_q=512, block_k=512):
    h, new_cache = attn_apply(
        p["attn"], cfg, norm_apply(p["ln1"], cfg, x),
        positions=positions, kv_cache=kv_cache, attn_impl=attn_impl,
        seq_spec=seq_spec, block_q=block_q, block_k=block_k,
    )
    x = x + h
    if moe:
        engine = moe_apply_shardmap if shard else moe_apply
        kw = {"dp_axes": dp_axes} if shard else {}
        f, aux = engine(p["ffn"], cfg, norm_apply(p["ln2"], cfg, x), **kw)
    else:
        f, aux = mlp_apply(p["ffn"], cfg, norm_apply(p["ln2"], cfg, x)), jnp.float32(0.0)
    return x + f, new_cache, aux


def attn_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, max_len, dh), dtype),
        "v": jnp.zeros((batch, hkv, max_len, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# cross-attention block (vlm)
# ---------------------------------------------------------------------------

def xattn_block_meta(cfg: ArchConfig):
    return {
        "ln1": norm_meta(cfg),
        "attn": attn_meta(cfg, cross=True),
        "ln2": norm_meta(cfg),
        "ffn": mlp_meta(cfg),
        "ffn_gate": ParamMeta((1,), F32, (None,), "zeros"),
    }


def xattn_block_apply(p, cfg: ArchConfig, x, memory=None, kv_override=None):
    h, _ = attn_apply(
        p["attn"], cfg, norm_apply(p["ln1"], cfg, x),
        memory=memory, kv_override=kv_override,
    )
    x = x + h
    f = mlp_apply(p["ffn"], cfg, norm_apply(p["ln2"], cfg, x))
    return x + f * jnp.tanh(p["ffn_gate"]).astype(f.dtype)


def xattn_precompute_kv(p, cfg: ArchConfig, memory):
    """Project the (fixed) image memory to K/V heads once for decode."""
    from .layers import _split_heads

    k = _split_heads(
        jnp.einsum("bsd,dh->bsh", memory, p["attn"]["wk"]), cfg.n_kv_heads, cfg.head_dim
    )
    v = _split_heads(
        jnp.einsum("bsd,dh->bsh", memory, p["attn"]["wv"]), cfg.n_kv_heads, cfg.head_dim
    )
    return k, v


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) block
# ---------------------------------------------------------------------------

def _rwkv_heads(cfg: ArchConfig):
    hd = cfg.ssm.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def rwkv6_block_meta(cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    lora = cfg.ssm.decay_lora
    H, hd = _rwkv_heads(cfg)
    return {
        "ln1": norm_meta(cfg),
        "ln2": norm_meta(cfg),
        # time-mix
        "mu": ParamMeta((5, d), F32, (None, None), "zeros"),   # r,k,v,w,g lerps
        "wr": ParamMeta((d, d), dt, ("fsdp", "tp"), "normal"),
        "wk": ParamMeta((d, d), dt, ("fsdp", "tp"), "normal"),
        "wv": ParamMeta((d, d), dt, ("fsdp", "tp"), "normal"),
        "wg": ParamMeta((d, d), dt, ("fsdp", "tp"), "normal"),
        "wo": ParamMeta((d, d), dt, ("tp", "fsdp"), "normal"),
        "w0": ParamMeta((d,), F32, (None,), "zeros"),          # decay base
        "wA": ParamMeta((d, lora), F32, ("fsdp", None), "normal"),
        "wB": ParamMeta((lora, d), F32, (None, "fsdp"), "normal"),
        "bonus": ParamMeta((H, hd), F32, (None, None), "zeros"),
        "gn": ParamMeta((d,), F32, (None,), "ones"),           # per-head groupnorm
        # channel-mix
        "cmu": ParamMeta((2, d), F32, (None, None), "zeros"),  # r,k lerps
        "cwr": ParamMeta((d, d), dt, ("fsdp", "tp"), "normal"),
        "cwk": ParamMeta((d, cfg.d_ff), dt, ("fsdp", "tp"), "normal"),
        "cwv": ParamMeta((cfg.d_ff, d), dt, ("tp", "fsdp"), "normal"),
    }


def _token_shift(x, prev):
    """x: (B,S,d); prev: (B,d) last token of previous segment (decode state)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def rwkv6_block_apply(p, cfg: ArchConfig, x, state=None, *, chunk=64):
    """state: None (fresh) or dict(tshift (B,d), cshift (B,d), h (B,H,K,V)).
    S > 1 runs the chunked scan (training/prefill, state-continuing);
    S == 1 with a state runs the O(1) recurrent step (decode)."""
    B, S, d = x.shape
    H, hd = _rwkv_heads(cfg)
    decode = state is not None and S == 1
    tprev = jnp.zeros((B, d), x.dtype) if state is None else state["tshift"].astype(x.dtype)
    cprev = jnp.zeros((B, d), x.dtype) if state is None else state["cshift"].astype(x.dtype)
    h0 = None if state is None else state["h"]

    # ---- time mix ----
    xa = norm_apply(p["ln1"], cfg, x)
    xs = _token_shift(xa, tprev)
    mu = p["mu"].astype(xa.dtype)

    def mix(i):
        return xa + (xs - xa) * mu[i]

    r = jnp.einsum("bsd,dk->bsk", mix(0), p["wr"])
    kk = jnp.einsum("bsd,dk->bsk", mix(1), p["wk"])
    vv = jnp.einsum("bsd,dk->bsk", mix(2), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,dk->bsk", mix(4), p["wg"]).astype(F32)).astype(xa.dtype)
    # data-dependent decay (low-rank, Finch)
    dw = jnp.tanh(jnp.einsum("bsd,dl->bsl", mix(3).astype(F32), p["wA"]))
    dw = jnp.einsum("bsl,ld->bsd", dw, p["wB"]) + p["w0"]
    w = jnp.exp(-jnp.exp(dw))                                   # (B,S,d) in (0,1)

    def to_heads(t):
        return t.reshape(B, S, H, hd).transpose(0, 2, 1, 3)

    rh, kh, vh, wh = to_heads(r), to_heads(kk), to_heads(vv), to_heads(w.astype(x.dtype))

    if decode:
        y1, hT = linear_scan_step(rh[:, :, 0], kh[:, :, 0], vh[:, :, 0],
                                  wh[:, :, 0], h0, strict=True)
        y = y1[:, :, None, :]
    else:
        y, hT = linear_scan_chunked(
            rh, kh, vh, wh, h0=h0, chunk=_pick_chunk(S, chunk), strict=True
        )
    # bonus: y += (r · (u ⊙ k)) v
    u = p["bonus"].astype(F32)
    s_bonus = jnp.einsum("bhsk,hk,bhsk->bhs", rh.astype(F32), u, kh.astype(F32))
    y = y.astype(F32) + s_bonus[..., None] * vh.astype(F32)

    # per-head groupnorm then output proj
    mean = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d) * p["gn"]
    y = (y.astype(x.dtype) * g)
    x = x + jnp.einsum("bsd,dk->bsk", y, p["wo"])

    # ---- channel mix ----
    xc = norm_apply(p["ln2"], cfg, x)
    xcs = _token_shift(xc, cprev)
    cmu = p["cmu"].astype(xc.dtype)
    xr = xc + (xcs - xc) * cmu[0]
    xk = xc + (xcs - xc) * cmu[1]
    kc = jnp.einsum("bsd,df->bsf", xk, p["cwk"])
    kc = jnp.square(jax.nn.relu(kc.astype(F32))).astype(xc.dtype)
    vc = jnp.einsum("bsf,fd->bsd", kc, p["cwv"])
    rc = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr, p["cwr"]).astype(F32)).astype(xc.dtype)
    x = x + rc * vc

    new_state = {"tshift": xa[:, -1, :], "cshift": xc[:, -1, :], "h": hT}
    return x, new_state


def rwkv6_state_init(cfg: ArchConfig, batch: int, dtype):
    H, hd = _rwkv_heads(cfg)
    return {
        "tshift": jnp.zeros((batch, cfg.d_model), dtype),
        "cshift": jnp.zeros((batch, cfg.d_model), dtype),
        "h": jnp.zeros((batch, H, hd, hd), F32),
    }


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    hd = cfg.ssm.head_dim
    assert d_inner % hd == 0
    H = d_inner // hd
    N = cfg.ssm.state
    return d_inner, H, hd, N


def mamba2_block_meta(cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    d_inner, H, hd, N = _mamba_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "ln": norm_meta(cfg),
        "in_proj": ParamMeta((d, 2 * d_inner + 2 * N + H), dt, ("fsdp", "tp"), "normal"),
        "conv_w": ParamMeta((cfg.ssm.conv, conv_dim), F32, (None, "tp"), "normal", scale=0.5),
        "conv_b": ParamMeta((conv_dim,), F32, ("tp",), "zeros"),
        "A_log": ParamMeta((H,), F32, (None,), "zeros"),
        "D": ParamMeta((H,), F32, (None,), "ones"),
        "dt_bias": ParamMeta((H,), F32, (None,), "zeros"),
        "gn": ParamMeta((d_inner,), F32, ("tp",), "ones"),
        "out_proj": ParamMeta((d_inner, d), dt, ("tp", "fsdp"), "normal"),
    }


def _causal_conv(x, w, b, prev):
    """x: (B,S,C); w: (K,C) depthwise; prev: (B,K-1,C) left context."""
    K = w.shape[0]
    xp = jnp.concatenate([prev, x], axis=1)                    # (B, S+K-1, C)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(K)
    )
    return out + b.astype(x.dtype), xp[:, -(K - 1):, :]


def mamba2_block_apply(p, cfg: ArchConfig, x, state=None, *, chunk=64):
    """state: None (fresh) or dict(conv (B,K-1,C), h (B,H,N,hd)).
    S > 1 runs the chunked scan; S == 1 with a state runs the decode step."""
    B, S, d = x.shape
    d_inner, H, hd, N = _mamba_dims(cfg)
    decode = state is not None and S == 1

    xa = norm_apply(p["ln"], cfg, x)
    proj = jnp.einsum("bsd,dp->bsp", xa, p["in_proj"])
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_inner + 2 * N], axis=-1)   # conv input, (B,S,H)

    conv_prev = (
        jnp.zeros((B, cfg.ssm.conv - 1, d_inner + 2 * N), xbc.dtype)
        if state is None else state["conv"].astype(xbc.dtype)
    )
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_prev)
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x.dtype)
    xin, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt_a = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"])     # (B,S,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, None] * dt_a)          # (B,S,H) decay

    # map onto the generalized scan: per head, k=B, q=C (shared), v=dt*x
    xh = xin.reshape(B, S, H, hd).transpose(0, 2, 1, 3)           # (B,H,S,hd)
    vh = xh * dt_a.transpose(0, 2, 1)[..., None].astype(xh.dtype)
    kh = jnp.broadcast_to(Bmat[:, None], (B, H, S, N)).astype(xh.dtype)
    qh = jnp.broadcast_to(Cmat[:, None], (B, H, S, N)).astype(xh.dtype)
    wh = jnp.broadcast_to(
        a.transpose(0, 2, 1)[..., None], (B, H, S, N)
    ).astype(xh.dtype)

    h0 = None if state is None else state["h"]
    if decode:
        y1, hT = linear_scan_step(qh[:, :, 0], kh[:, :, 0], vh[:, :, 0], wh[:, :, 0], h0)
        y = y1[:, :, None, :]
    else:
        y, hT = linear_scan_chunked(qh, kh, vh, wh, h0=h0, chunk=_pick_chunk(S, chunk))

    y = y.astype(F32) + p["D"][None, :, None, None] * xh.astype(F32)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d_inner)

    # gated RMSNorm (mamba2) then out proj (gate silu in f32: §Perf zamba2
    # it3 tested a bf16 gate to shrink the backward's f32 dproj gather —
    # refuted, zero byte change — so the f32 gate stays for numerics)
    y = y * jax.nn.silu(z.astype(F32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * p["gn"]
    out = jnp.einsum("bsp,pd->bsd", y.astype(x.dtype), p["out_proj"])

    new_state = {"conv": conv_state, "h": hT}
    return x + out, new_state


def mamba2_state_init(cfg: ArchConfig, batch: int, dtype):
    d_inner, H, hd, N = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv - 1, d_inner + 2 * N), dtype),
        "h": jnp.zeros((batch, H, N, hd), F32),
    }
