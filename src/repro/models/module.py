"""Minimal pure-pytree module system.

One source of truth per layer: a *meta tree* of :class:`ParamMeta` leaves
(shape, dtype, partition spec, init rule).  From the meta tree we derive

  * materialized parameters (``build_params`` — used by smoke tests/training),
  * ``jax.ShapeDtypeStruct`` stand-ins (``build_shapes`` — used by dry-runs;
    no allocation ever happens),
  * the matching ``PartitionSpec`` tree (``build_pspecs`` — consumed by
    pjit in/out shardings).

Sharding vocabulary (resolved against the production mesh):
  * ``"fsdp"``  — parameter/optimizer sharding over the data-parallel axes
    (("pod","data") on the multi-pod mesh, "data" on one pod) — ZeRO-3.
  * ``"tp"``    — tensor parallelism over the "model" axis.
  * ``None``    — replicated.
Logical names keep layer definitions mesh-agnostic; ``resolve_spec`` maps
them to concrete mesh axes at lower time.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class ParamMeta(NamedTuple):
    shape: tuple
    dtype: Any
    spec: tuple          # logical names per dim: "fsdp" | "tp" | None
    init: str            # "normal" | "zeros" | "ones" | "embed"
    scale: float = 1.0   # multiplier on the init std


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def _leaf_init(key, meta: ParamMeta):
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, meta.dtype)
    if meta.init == "ones":
        return jnp.ones(meta.shape, meta.dtype)
    if meta.init == "embed":
        std = meta.scale
        return (jax.random.normal(key, meta.shape, jnp.float32) * std).astype(meta.dtype)
    if meta.init == "normal":
        fan_in = meta.shape[-2] if len(meta.shape) >= 2 else meta.shape[-1]
        std = meta.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, meta.shape, jnp.float32) * std).astype(meta.dtype)
    raise ValueError(meta.init)


def build_params(meta_tree, key):
    """Materialize parameters from a meta tree (pure jax; eval_shape-safe)."""
    leaves, treedef = jax.tree.flatten(meta_tree, is_leaf=is_meta)
    keys = jax.random.split(key, len(leaves))
    params = [_leaf_init(k, m) for k, m in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, params)


def build_shapes(meta_tree):
    """ShapeDtypeStruct stand-ins (for .lower() without allocation)."""
    return jax.tree.map(
        lambda m: jax.ShapeDtypeStruct(m.shape, m.dtype), meta_tree, is_leaf=is_meta
    )


def resolve_spec(logical: Sequence, *, multi_pod: bool) -> P:
    """Map logical dim names to mesh axes."""
    fsdp = ("pod", "data") if multi_pod else "data"
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        elif name == "fsdp":
            out.append(fsdp)
        elif name == "tp":
            out.append("model")
        elif name == "dp":
            out.append(("pod", "data") if multi_pod else "data")
        else:
            raise ValueError(f"unknown logical axis {name}")
    return P(*out)


def build_pspecs(meta_tree, *, multi_pod: bool):
    return jax.tree.map(
        lambda m: resolve_spec(m.spec, multi_pod=multi_pod), meta_tree, is_leaf=is_meta
    )


def stack_meta(meta_tree, n: int):
    """Prepend a stacked-layer dimension (for lax.scan over layers)."""
    return jax.tree.map(
        lambda m: ParamMeta((n,) + tuple(m.shape), m.dtype, (None,) + tuple(m.spec),
                            m.init, m.scale),
        meta_tree,
        is_leaf=is_meta,
    )


def build_params_stacked(meta_tree_single, n: int, key):
    """Init n stacked copies by vmapping the per-layer init over keys."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: build_params(meta_tree_single, k))(keys)


def param_count(meta_tree) -> int:
    leaves = jax.tree.leaves(meta_tree, is_leaf=is_meta)
    return sum(int(math.prod(m.shape)) for m in leaves)


def param_bytes(meta_tree) -> int:
    leaves = jax.tree.leaves(meta_tree, is_leaf=is_meta)
    return sum(int(math.prod(m.shape)) * jnp.dtype(m.dtype).itemsize for m in leaves)
