"""Full decoder LM assembly for every assigned architecture family.

Compile-size discipline (one CPU must compile 512-device SPMD programs):
  * parameters for the repeated stack are **stacked** (leading layer dim) and
    the stack runs under ``lax.scan`` — HLO size is layer-count independent;
  * heterogeneous interleaves (zamba2 shared attention, llama-3.2-vision
    cross-attention) stay inside the same scan via ``lax.cond`` on the layer
    index (one copy of each block kind in the HLO);
  * attention is chunked (linear memory) and the loss is computed in
    sequence chunks so the (B, S, vocab) logits tensor never materializes.

Sharding: weights carry logical specs (module.py); activations get
sequence-parallel constraints at block boundaries and head-parallel
constraints inside attention when ``run["sp"]`` is set.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import blocks as B
from . import layers as L
from .config import ArchConfig
from .module import (
    ParamMeta,
    build_params,
    build_params_stacked,
    build_pspecs,
    build_shapes,
    stack_meta,
)

F32 = jnp.float32

DEFAULT_RUN: Dict[str, Any] = {
    "attn_impl": "chunked",   # "chunked" | "kernel"
    "sp": False,              # sequence-parallel activation constraints
    "remat": True,            # per-layer activation checkpointing
    "loss_chunk": 512,        # sequence chunk for the xent loss
    "dp_axes": ("data",),     # data axes for activation constraints
}


def _constrain(x, spec, run):
    if run.get("sp"):
        return jax.lax.with_sharding_constraint(x, spec)
    return x


class LM:
    """Config-driven decoder LM: meta/init/loss/forward/decode."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        fam = cfg.family
        if fam in ("dense", "moe", "audio", "vlm"):
            self.block_kind = "attn"
        elif fam == "ssm":
            self.block_kind = "rwkv6"
        elif fam == "hybrid":
            self.block_kind = "mamba2"
        else:
            raise ValueError(fam)

    # -- parameter metadata -------------------------------------------------
    def meta(self):
        cfg = self.cfg
        if self.block_kind == "attn":
            block = B.attn_block_meta(cfg, moe=cfg.moe is not None)
        elif self.block_kind == "rwkv6":
            block = B.rwkv6_block_meta(cfg)
        else:
            block = B.mamba2_block_meta(cfg)

        m = {
            "embed": L.embed_meta(cfg),
            "blocks": stack_meta(block, cfg.n_layers),
            "ln_f": L.norm_meta(cfg),
        }
        if cfg.shared_attn_every:
            m["shared_attn"] = B.attn_block_meta(cfg, moe=False)
        if cfg.xattn_every:
            n_x = cfg.n_layers // cfg.xattn_every
            m["xattn"] = stack_meta(B.xattn_block_meta(cfg), n_x)
        return m

    def init(self, key):
        cfg = self.cfg
        m = self.meta()
        keys = jax.random.split(key, 4)
        params = {
            "embed": build_params(m["embed"], keys[0]),
            "ln_f": build_params(m["ln_f"], keys[1]),
        }
        if self.block_kind == "attn":
            block = B.attn_block_meta(cfg, moe=cfg.moe is not None)
        elif self.block_kind == "rwkv6":
            block = B.rwkv6_block_meta(cfg)
        else:
            block = B.mamba2_block_meta(cfg)
        params["blocks"] = build_params_stacked(block, cfg.n_layers, keys[2])
        if cfg.shared_attn_every:
            params["shared_attn"] = build_params(
                B.attn_block_meta(cfg, moe=False), keys[3]
            )
        if cfg.xattn_every:
            n_x = cfg.n_layers // cfg.xattn_every
            params["xattn"] = build_params_stacked(
                B.xattn_block_meta(cfg), n_x, keys[3]
            )
        return params

    def shapes(self):
        return build_shapes(self.meta())

    def pspecs(self, *, multi_pod: bool):
        return build_pspecs(self.meta(), multi_pod=multi_pod)

    # -- forward (training / prefill) ---------------------------------------
    def hidden_states(self, params, tokens, *, memory=None, run=None,
                      positions=None, states=None):
        """Embeds and runs the block stack.  Returns (hidden, aux_loss,
        new_states) — states are the recurrent decode states (ssm/hybrid)
        produced even in training (used by prefill-to-decode handoff)."""
        cfg = self.cfg
        run = {**DEFAULT_RUN, **(run or {})}
        dp = run["dp_axes"]

        x = L.embed_apply(params["embed"], cfg, tokens)
        if not cfg.rope and self.block_kind == "attn":
            S = x.shape[1]
            pos = positions if positions is not None else jnp.arange(S)
            x = x + L.sinusoid_embed(pos, cfg.d_model)[None].astype(x.dtype)

        if self.block_kind == "attn":
            sp_spec = P(dp, "model", None)       # sequence-parallel residual
        else:
            sp_spec = P(dp, None, "model")       # d-sharded (see _recurrent_stack)
        x = _constrain(x, sp_spec, run)

        if self.block_kind == "attn":
            out = self._attn_stack(params, x, memory, run, positions)
        elif self.block_kind == "rwkv6":
            out = self._recurrent_stack(params, x, run, B.rwkv6_block_apply, states)
        else:
            out = self._hybrid_stack(params, x, run, positions, states)
        x, aux, new_states = out
        x = L.norm_apply(params["ln_f"], cfg, x)
        return x, aux, new_states

    def _attn_stack(self, params, x, memory, run, positions):
        cfg = self.cfg
        moe = cfg.moe is not None
        sp_spec = P(run["dp_axes"], "model", None)

        def body(carry, layer_params):
            h = carry
            h2, _, aux = B.attn_block_apply(
                layer_params, cfg, h, moe=moe, positions=positions,
                attn_impl=run["attn_impl"],
                dp_axes=run["dp_axes"], shard=run.get("sp", False),
                seq_spec=(
                    (run["dp_axes"], "model")
                    if run.get("sp") and run.get("attn_seq_shard") else None
                ),
                block_q=run.get("attn_block_q", 512),
                block_k=run.get("attn_block_k", 512),
            )
            # sequence-parallel residual boundary: the scan carry (the only
            # per-layer tensor the remat'd backward stores) is sharded over
            # (data × model)
            h2 = _constrain(h2, sp_spec, run)
            return h2, aux

        if cfg.xattn_every is None:
            if run["remat"]:
                body = jax.checkpoint(body)
            x, auxs = jax.lax.scan(body, x, params["blocks"])
            return x, jnp.sum(auxs), None

        # VLM: group scan — `every` self-attn layers then one cross-attn
        # block per group.  A per-layer lax.cond would (a) schedule a branch
        # dispatch every layer and (b) make the compiled while body carry
        # the cross-attn cost on every iteration — the group structure is
        # both the cheaper program and the honestly-countable one.
        every = cfg.xattn_every
        n_groups = cfg.n_layers // every

        if run["remat"]:
            # nested remat: the group backward recomputes its layers one at
            # a time — without the inner checkpoint all `every` layers'
            # attention buffers are live at once during the group's bwd
            # (measured 22 GiB/dev at llama-3.2-vision train_4k).
            body = jax.checkpoint(body)

        def group_body(carry, xs):
            h = carry
            glp, xp = xs
            h, auxs = jax.lax.scan(body, h, glp)
            h = B.xattn_block_apply(xp, cfg, h, memory)
            h = _constrain(h, sp_spec, run)
            return h, jnp.sum(auxs)

        if run["remat"]:
            group_body = jax.checkpoint(group_body)
        grouped = jax.tree.map(
            lambda a: a[: n_groups * every].reshape(
                (n_groups, every) + a.shape[1:]
            ),
            params["blocks"],
        )
        x, auxs = jax.lax.scan(group_body, x, (grouped, params["xattn"]))
        return x, jnp.sum(auxs), None

    def _recurrent_stack(self, params, x, run, block_apply, states):
        cfg = self.cfg
        # SSM stacks: shard d_model (not seq) over "model".  The recurrence
        # chunk-scans slice the seq dim; with seq sharded over "model" XLA
        # all-gathers the full residual (B_dev×S×d, 537 MB at zamba2
        # train_4k) per layer per pass — d-sharding keeps every slice local
        # (§Perf iteration: zamba2/rwkv6).
        sp_spec = P(run["dp_axes"], None, "model")

        def body(h, xs):
            layer_params, st = xs
            h2, new_st = block_apply(layer_params, cfg, h, state=st)
            h2 = _constrain(h2, sp_spec, run)
            return h2, new_st

        if run["remat"]:
            body = jax.checkpoint(body)
        x, new_states = jax.lax.scan(body, x, (params["blocks"], states))
        return x, jnp.float32(0.0), new_states

    def _hybrid_stack(self, params, x, run, positions, states):
        """Zamba2-style: group scan of `every` mamba layers + the shared
        attention block once per group (+ a mamba tail for the remainder).
        A per-layer lax.cond would schedule (and cost) the attention branch
        on every one of the 38 iterations instead of 6."""
        cfg = self.cfg
        every = cfg.shared_attn_every
        # d-sharded residual for the mamba backbone (see _recurrent_stack)
        sp_spec = P(run["dp_axes"], None, "model")
        n_groups = cfg.n_layers // every
        n_head = n_groups * every

        def mamba_body(h, xs):
            layer_params, st = xs
            h2, new_st = B.mamba2_block_apply(layer_params, cfg, h, state=st)
            return _constrain(h2, sp_spec, run), new_st

        def group_body(h, xs):
            glp, gst = xs
            h, new_st = jax.lax.scan(mamba_body, h, (glp, gst))
            # seq_spec deliberately None: the d-sharded mamba residual feeds
            # this block, and pinning the seq-parallel attention layout here
            # measured +2.1 s of ICI (§Perf zamba2 it3b) — propagation wins.
            h, _, _ = B.attn_block_apply(
                params["shared_attn"], cfg, h, moe=False,
                positions=positions, attn_impl=run["attn_impl"],
            )
            return _constrain(h, sp_spec, run), new_st

        mamba_tail = mamba_body
        if run["remat"]:
            group_body = jax.checkpoint(group_body)
            mamba_tail = jax.checkpoint(mamba_body)

        def group(a):
            return a[:n_head].reshape((n_groups, every) + a.shape[1:])

        x, ns_head = jax.lax.scan(
            group_body, x,
            (jax.tree.map(group, params["blocks"]), jax.tree.map(group, states)),
        )
        ns_head = jax.tree.map(
            lambda a: a.reshape((n_head,) + a.shape[2:]), ns_head
        )
        if n_head == cfg.n_layers:
            return x, jnp.float32(0.0), ns_head

        def tail(a):
            return a[n_head:]

        x, ns_tail = jax.lax.scan(
            mamba_tail, x,
            (jax.tree.map(tail, params["blocks"]), jax.tree.map(tail, states)),
        )
        new_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ns_head, ns_tail
        )
        return x, jnp.float32(0.0), new_states

    def init_recurrent_states(self, batch: int, dtype):
        """Stacked per-layer recurrent states for ssm/hybrid stacks."""
        cfg = self.cfg
        if self.block_kind == "rwkv6":
            one = B.rwkv6_state_init(cfg, batch, dtype)
        elif self.block_kind == "mamba2":
            one = B.mamba2_state_init(cfg, batch, dtype)
        else:
            return None
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one
        )

    # -- loss ----------------------------------------------------------------
    def loss(self, params, batch, *, run=None):
        """batch: dict(tokens (B,S) or (B,S,ncb), targets same, mask (B,S))."""
        cfg = self.cfg
        run = {**DEFAULT_RUN, **(run or {})}
        tokens, targets = batch["tokens"], batch["targets"]
        mask = batch.get("mask")
        memory = batch.get("memory")
        states = (
            self.init_recurrent_states(tokens.shape[0], cfg.param_dtype)
            if self.block_kind in ("rwkv6", "mamba2")
            else None
        )
        hid, aux, _ = self.hidden_states(
            params, tokens, memory=memory, run=run, states=states
        )
        nll = _xent_chunked(
            params["embed"], cfg, hid, targets, mask, chunk=run["loss_chunk"]
        )
        loss = nll + 0.01 * aux
        return loss

    # -- decode ---------------------------------------------------------------
    def decode_init(self, batch: int, max_len: int, *, params=None, memory=None):
        """Allocate the decode cache pytree.  For vlm archs pass params +
        image memory: cross-attention K/V are projected once here instead of
        per decode step."""
        cfg = self.cfg
        dt = cfg.param_dtype
        cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
        if self.block_kind == "attn":
            kv_len = min(max_len, cfg.window) if cfg.window else max_len
            cache["kv"] = {
                "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, kv_len, cfg.head_dim), dt),
                "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, kv_len, cfg.head_dim), dt),
            }
            if cfg.xattn_every and memory is not None and params is not None:
                n_x = cfg.n_layers // cfg.xattn_every
                xks, xvs = [], []
                for i in range(n_x):
                    xp = jax.tree.map(lambda a: a[i], params["xattn"])
                    xk, xv = B.xattn_precompute_kv(xp, cfg, memory)
                    xks.append(xk)
                    xvs.append(xv)
                cache["xkv"] = {"k": jnp.stack(xks), "v": jnp.stack(xvs)}
        elif self.block_kind == "rwkv6":
            cache["states"] = self.init_recurrent_states(batch, dt)
        else:
            cache["states"] = self.init_recurrent_states(batch, dt)
            n_occ = cfg.n_layers // cfg.shared_attn_every
            kv_len = min(max_len, cfg.window) if cfg.window else max_len
            cache["shared_kv"] = {
                "k": jnp.zeros((n_occ, batch, cfg.n_kv_heads, kv_len, cfg.head_dim), dt),
                "v": jnp.zeros((n_occ, batch, cfg.n_kv_heads, kv_len, cfg.head_dim), dt),
            }
        return cache

    def decode_step(self, params, tokens, cache, *, memory=None, run=None):
        """One token per sequence. tokens: (B, 1) or (B, 1, ncb)."""
        cfg = self.cfg
        run = {**DEFAULT_RUN, **(run or {}), "remat": False}
        pos = cache["len"]
        x = L.embed_apply(params["embed"], cfg, tokens)
        if not cfg.rope and self.block_kind == "attn":
            x = x + L.sinusoid_embed(pos[None], cfg.d_model)[None].astype(x.dtype)

        if self.block_kind == "attn":
            x, new_cache = self._attn_decode(params, x, cache, memory, run)
        elif self.block_kind == "rwkv6":
            def body(h, xs):
                lp, st = xs
                h2, nst = B.rwkv6_block_apply(lp, cfg, h, state=st)
                return h2, nst
            x, nstates = jax.lax.scan(body, x, (params["blocks"], cache["states"]))
            new_cache = {**cache, "states": nstates, "len": pos + 1}
        else:
            x, new_cache = self._hybrid_decode(params, x, cache, run)

        x = L.norm_apply(params["ln_f"], cfg, x)
        logits = self._logits(params, x)
        return logits, new_cache

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.n_codebooks > 1:
            outs = [
                L.logits_apply(params["embed"], cfg, x, codebook=c)
                for c in range(cfg.n_codebooks)
            ]
            return jnp.stack(outs, axis=2)  # (B, S, ncb, Vp)
        return L.logits_apply(params["embed"], cfg, x)

    def _attn_decode(self, params, x, cache, memory, run):
        cfg = self.cfg
        pos = cache["len"]
        positions = pos + jnp.arange(x.shape[1])
        has_x = cfg.xattn_every and "xkv" in cache

        start = cache.get("start")  # (B,) slot admission offsets (serving)
        # decode hidden is tiny (B,1,d): keeping it replicated over "model"
        # removes the per-layer all-gather before each projection (§Perf:
        # mixtral decode_32k iteration) at the cost of nothing — the psum
        # after row-sharded projections already exists.
        if run.get("decode_pin_replicated"):
            def pin(t):
                return jax.lax.with_sharding_constraint(
                    t, P(run["dp_axes"], None, None))
        elif run.get("decode_pin_dshard"):
            def pin(t):
                return jax.lax.with_sharding_constraint(
                    t, P(run["dp_axes"], None, "model"))
        else:
            def pin(t):
                return t

        def body(carry, xs):
            h = pin(carry)
            lp, k_l, v_l = xs
            kv = {"k": k_l, "v": v_l, "len": pos, "start": start}
            h2, new_kv, _ = B.attn_block_apply(
                lp, cfg, h, moe=cfg.moe is not None, positions=positions,
                kv_cache=kv, attn_impl="chunked",
                dp_axes=run["dp_axes"],
                shard=bool(run.get("decode_moe_shardmap")),
            )
            return pin(h2), (new_kv["k"], new_kv["v"])

        if not has_x:
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"])
            )
            new_cache = {**cache, "kv": {"k": nk, "v": nv}, "len": pos + 1}
            return x, new_cache

        # VLM decode: group scan, cross-attn once per group (see _attn_stack)
        every = cfg.xattn_every
        n_groups = cfg.n_layers // every

        def group_body(carry, xs):
            h = carry
            glp, gk, gv, xp, xk, xv = xs
            h, (nk, nv) = jax.lax.scan(body, h, (glp, gk, gv))
            h = B.xattn_block_apply(xp, cfg, h, kv_override=(xk, xv))
            return pin(h), (nk, nv)

        def group(a):
            return a.reshape((n_groups, every) + a.shape[1:])

        x, (nk, nv) = jax.lax.scan(
            group_body, x,
            (
                jax.tree.map(group, params["blocks"]),
                group(cache["kv"]["k"]), group(cache["kv"]["v"]),
                params["xattn"], cache["xkv"]["k"], cache["xkv"]["v"],
            ),
        )
        nk = nk.reshape((cfg.n_layers,) + nk.shape[2:])
        nv = nv.reshape((cfg.n_layers,) + nv.shape[2:])
        new_cache = {**cache, "kv": {"k": nk, "v": nv}, "len": pos + 1}
        return x, new_cache

    def _hybrid_decode(self, params, x, cache, run):
        """Group scan mirroring _hybrid_stack: `every` mamba steps then the
        shared attention block against its per-occurrence KV cache."""
        cfg = self.cfg
        pos = cache["len"]
        every = cfg.shared_attn_every
        positions = pos + jnp.arange(x.shape[1])
        n_groups = cfg.n_layers // every
        n_head = n_groups * every

        def mamba_body(h, xs):
            lp, st = xs
            h2, nst = B.mamba2_block_apply(lp, cfg, h, state=st)
            return h2, nst

        def group_body(carry, xs):
            h = carry
            glp, gst, sk, sv = xs
            h, nst = jax.lax.scan(mamba_body, h, (glp, gst))
            kv = {"k": sk, "v": sv, "len": pos, "start": cache.get("start")}
            h, new_kv, _ = B.attn_block_apply(
                params["shared_attn"], cfg, h, moe=False,
                positions=positions, kv_cache=kv, attn_impl="chunked",
            )
            return h, (nst, new_kv["k"], new_kv["v"])

        def group(a):
            return a[:n_head].reshape((n_groups, every) + a.shape[1:])

        x, (ns_head, sk, sv) = jax.lax.scan(
            group_body, x,
            (
                jax.tree.map(group, params["blocks"]),
                jax.tree.map(group, cache["states"]),
                cache["shared_kv"]["k"], cache["shared_kv"]["v"],
            ),
        )
        ns_head = jax.tree.map(
            lambda a: a.reshape((n_head,) + a.shape[2:]), ns_head
        )
        if n_head == cfg.n_layers:
            nstates = ns_head
        else:

            def tail(a):
                return a[n_head:]

            x, ns_tail = jax.lax.scan(
                mamba_body, x,
                (jax.tree.map(tail, params["blocks"]),
                 jax.tree.map(tail, cache["states"])),
            )
            nstates = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), ns_head, ns_tail
            )
        new_cache = {
            **cache,
            "states": nstates,
            "shared_kv": {"k": sk, "v": sv},
            "len": pos + 1,
        }
        return x, new_cache


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes full logits)
# ---------------------------------------------------------------------------

def _xent_chunked(embed_params, cfg: ArchConfig, hidden, targets, mask, *, chunk):
    B_, S, d = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk
    vp = L.padded_vocab(cfg)

    hid = hidden.reshape(B_, n, chunk, d).transpose(1, 0, 2, 3)
    if cfg.n_codebooks > 1:
        tgt = targets.reshape(B_, n, chunk, cfg.n_codebooks).transpose(1, 0, 2, 3)
    else:
        tgt = targets.reshape(B_, n, chunk).transpose(1, 0, 2)
    msk = (
        mask.reshape(B_, n, chunk).transpose(1, 0, 2).astype(F32)
        if mask is not None
        else jnp.ones((n, B_, chunk), F32)
    )

    pad_penalty = jnp.where(jnp.arange(vp) >= cfg.vocab, -1e30, 0.0)

    def body(acc, xs):
        h, t, m = xs
        tot, cnt = acc
        if cfg.n_codebooks > 1:
            nll = 0.0
            for c in range(cfg.n_codebooks):
                lg = L.logits_apply(embed_params, cfg, h, codebook=c).astype(F32)
                lg = lg + pad_penalty
                lse = jax.nn.logsumexp(lg, axis=-1)
                gold = jnp.take_along_axis(lg, t[..., c][..., None], axis=-1)[..., 0]
                nll = nll + (lse - gold)
            nll = nll / cfg.n_codebooks
        else:
            lg = L.logits_apply(embed_params, cfg, h).astype(F32)
            lg = lg + pad_penalty
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
            nll = lse - gold
        return (tot + jnp.sum(nll * m), cnt + jnp.sum(m)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hid, tgt, msk))
    return tot / jnp.maximum(cnt, 1.0)
