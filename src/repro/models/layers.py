"""Core layers: norm, RoPE, embeddings, attention, MLP, MoE.

Every layer is a (meta, apply) pair — see ``module.py``.  Activation layout
is (B, S, d_model); attention internals use (B, H, S, Dh).  All reductions
accumulate in f32.  Sharding: weights carry logical ("fsdp", "tp") specs;
activations get ``with_sharding_constraint`` at block boundaries (sequence
parallelism: seq dim over "model" on the residual stream).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

try:  # jax < 0.5 keeps shard_map under jax.experimental
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.kernels.flash_attention import attention as flash_attention
from repro.kernels.flash_attention.ref import mha_chunked

from .config import ArchConfig
from .module import ParamMeta

F32 = jnp.float32


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_meta(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    m = {"scale": ParamMeta((d,), F32, (None,), "ones")}
    if cfg.norm == "layernorm" and cfg.norm_bias:
        m["bias"] = ParamMeta((d,), F32, (None,), "zeros")
    return m


def norm_apply(p, cfg: ArchConfig, x):
    xf = x.astype(F32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"]
        if "bias" in p:
            out = out + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_apply(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    B, H, S, D = x.shape
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    if positions.ndim == 1:
        ang = positions.astype(F32)[:, None] * freqs[None, :]        # (S, half)
        ang = ang[None, None]                                        # (1,1,S,half)
    else:
        ang = positions.astype(F32)[:, None, :, None] * freqs[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_embed(positions, d: int):
    """positions: (S,) int -> (S, d) sinusoidal embedding (no table)."""
    pos = positions.astype(F32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=F32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / lm head
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def padded_vocab(cfg: ArchConfig) -> int:
    return round_up(cfg.vocab, 128)  # TP-16 friendly for every assigned arch


def embed_meta(cfg: ArchConfig):
    vp = padded_vocab(cfg)
    m = {
        "tok": ParamMeta(
            (cfg.n_codebooks, vp, cfg.d_model) if cfg.n_codebooks > 1 else (vp, cfg.d_model),
            cfg.param_dtype,
            ((None, "tp", "fsdp") if cfg.n_codebooks > 1 else ("tp", "fsdp")),
            "embed",
            scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        m["head"] = ParamMeta(
            (cfg.n_codebooks, cfg.d_model, vp) if cfg.n_codebooks > 1 else (cfg.d_model, vp),
            cfg.param_dtype,
            ((None, "fsdp", "tp") if cfg.n_codebooks > 1 else ("fsdp", "tp")),
            "normal",
        )
    return m


def embed_apply(p, cfg: ArchConfig, tokens):
    """tokens: (B, S) int32, or (B, S, n_codebooks) for audio."""
    if cfg.n_codebooks > 1:
        # sum of per-codebook embeddings (MusicGen)
        out = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), cfg.param_dtype)
        for c in range(cfg.n_codebooks):
            out = out + jnp.take(p["tok"][c], tokens[..., c], axis=0)
        return out
    return jnp.take(p["tok"], tokens, axis=0)


def logits_apply(p, cfg: ArchConfig, x, codebook: Optional[int] = None):
    """x: (B, S, d) -> (B, S, padded_vocab) (per codebook for audio)."""
    if cfg.tie_embeddings:
        w = p["tok"].astype(cfg.param_dtype)
        if cfg.n_codebooks > 1:
            w = w[codebook]
        return jnp.einsum("bsd,vd->bsv", x, w)
    w = p["head"] if cfg.n_codebooks == 1 else p["head"][codebook]
    return jnp.einsum("bsd,dv->bsv", x, w)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_meta(cfg: ArchConfig, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    m = {
        "wq": ParamMeta((d, hq * dh), dt, ("fsdp", "tp"), "normal"),
        "wk": ParamMeta((d, hkv * dh), dt, ("fsdp", "tp"), "normal"),
        "wv": ParamMeta((d, hkv * dh), dt, ("fsdp", "tp"), "normal"),
        "wo": ParamMeta((hq * dh, d), dt, ("tp", "fsdp"), "normal"),
    }
    if cfg.qkv_bias:
        m["bq"] = ParamMeta((hq * dh,), F32, ("tp",), "zeros")
        m["bk"] = ParamMeta((hkv * dh,), F32, ("tp",), "zeros")
        m["bv"] = ParamMeta((hkv * dh,), F32, ("tp",), "zeros")
    if cross:
        m["gate"] = ParamMeta((1,), F32, (None,), "zeros")  # tanh-gated (llama-3.2)
    return m


def _split_heads(x, n_heads, d_head):
    B, S, _ = x.shape
    return x.reshape(B, S, n_heads, d_head).transpose(0, 2, 1, 3)


def _decode_attention(q, k, v, valid, start=None):
    """q: (B,Hq,1,Dh); k,v: (B,Hkv,T,Dh); attend over slots < valid.

    ``start`` (B,) optionally masks slots below a per-sequence admission
    offset — the continuous-batching serving engine reuses cache slots, and
    a re-admitted sequence must not attend to its predecessor's stale KV
    rows (valid while the cache has not wrapped; the engine resets slots
    only in the unwrapped regime)."""
    B, Hq, S, Dh = q.shape
    _, Hkv, T, _ = k.shape
    g = Hq // Hkv
    qf = q.reshape(B, Hkv, g, S, Dh).astype(F32) * (Dh ** -0.5)
    s = jnp.einsum("bhgsd,bhtd->bhgst", qf, k.astype(F32))
    slot = jnp.arange(T)
    mask = slot[None, :] < jnp.broadcast_to(valid, (B,))[:, None]
    if start is not None:
        mask = mask & (slot[None, :] >= start[:, None])
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(F32))
    return out.reshape(B, Hq, S, Dh).astype(q.dtype)


def attn_apply(
    p,
    cfg: ArchConfig,
    x,                      # (B, S, d)
    *,
    positions=None,         # (S,) absolute positions (for rope)
    kv_cache=None,          # optional dict(k=(B,Hkv,T,Dh), v=..., len=())
    memory=None,            # (B, M, d) cross-attention memory
    kv_override=None,       # precomputed (k, v) heads (cross-attn decode)
    attn_impl: str = "chunked",
    block_k: int = 512,
    block_q: int = 512,
    seq_spec=None,          # (dp_axes, model_axis): seq-parallel attn layout
):
    """Returns (out, new_kv_cache or None).

    Decode caches are ring buffers of capacity T (= window for SWA archs):
    the step writes at ``len % T`` and attends over ``min(len+1, T)`` valid
    slots.  RoPE is applied pre-cache, so slot order within the ring is
    irrelevant (attention is permutation-invariant over keys).
    """
    B, S, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cross = memory is not None or kv_override is not None

    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), hq, dh)
    if kv_override is not None:
        k, v = kv_override
    else:
        kv_src = memory if cross else x
        k = _split_heads(jnp.einsum("bsd,dh->bsh", kv_src, p["wk"]), hkv, dh)
        v = _split_heads(jnp.einsum("bsd,dh->bsh", kv_src, p["wv"]), hkv, dh)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, 1, dh).astype(q.dtype)
        if kv_override is None:
            k = k + p["bk"].reshape(hkv, 1, dh).astype(k.dtype)
            v = v + p["bv"].reshape(hkv, 1, dh).astype(v.dtype)

    if cfg.rope and not cross:
        if positions is None:
            positions = jnp.arange(S)
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and kv_cache.get("collect") is not None:
        # prefill collection: full-sequence attention, but also hand the
        # projected k/v back to the caller (page writer)
        out = mha_chunked(
            q, k, v, causal=True,
            window=cfg.window, block_k=block_k,
        )
        new_cache = {"k": k, "v": v}
    elif kv_cache is not None:
        # decode (S == 1): ring-buffer append + attend over valid slots
        T = kv_cache["k"].shape[2]
        idx = kv_cache["len"]
        write = jax.lax.rem(idx, T)
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, write, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, write, axis=2)
        new_cache = {"k": ck, "v": cv, "len": idx + S}
        valid = jnp.minimum(idx + S, T)
        # direct masked attention: S==1 keeps memory linear, and when the
        # cache T axis is sharded over "model" the softmax reduction becomes
        # the flash-decoding partial-softmax merge (psum over "model") under
        # SPMD — no gather of the KV stripes.
        out = _decode_attention(q, ck, cv, valid, start=kv_cache.get("start"))
    else:
        causal = not cross
        if attn_impl == "kernel":
            out = flash_attention(q, k, v, causal=causal, window=cfg.window)
        else:
            out = mha_chunked(
                q, k, v, causal=causal,
                window=cfg.window if not cross else None,
                block_k=block_k, block_q=block_q,
                seq_spec=seq_spec if not cross else None,
            )

    out = out.transpose(0, 2, 1, 3).reshape(B, S, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    if cross:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out, new_cache


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp_meta(cfg: ArchConfig):
    d, ff, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    if cfg.act == "swiglu":
        m = {
            "wi": ParamMeta((d, ff), dt, ("fsdp", "tp"), "normal"),
            "wg": ParamMeta((d, ff), dt, ("fsdp", "tp"), "normal"),
            "wo": ParamMeta((ff, d), dt, ("tp", "fsdp"), "normal"),
        }
    else:
        m = {
            "wi": ParamMeta((d, ff), dt, ("fsdp", "tp"), "normal"),
            "wo": ParamMeta((ff, d), dt, ("tp", "fsdp"), "normal"),
        }
    if cfg.mlp_bias:
        m["bi"] = ParamMeta((ff,), F32, ("tp",), "zeros")
        m["bo"] = ParamMeta((d,), F32, (None,), "zeros")
    return m


def mlp_apply(p, cfg: ArchConfig, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_bias:
        h = h + p["bi"].astype(h.dtype)
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g.astype(F32)).astype(h.dtype) * h
    else:
        h = jax.nn.gelu(h.astype(F32)).astype(h.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    if cfg.mlp_bias:
        out = out + p["bo"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# MoE (capacity-based top-k dispatch; deterministic phase-order drops)
#
# Two engines:
#   * moe_apply          — pure-jnp global dispatch (CPU smoke tests, and the
#                          oracle for the sharded path);
#   * moe_apply_shardmap — production path: token-local dispatch per data
#                          shard under shard_map.  Expert weights are
#                          FSDP-all-gathered explicitly (per layer, inside
#                          the remat'd scan body), the expert FFN contracts
#                          its TP-sharded hidden width locally, and one psum
#                          over "model" completes the block — the same
#                          collective budget as the dense TP FFN, zero
#                          cross-shard scatter traffic.  XLA's scatter
#                          sharding propagation is too weak to get there
#                          from the global formulation (measured: 300 GiB/dev
#                          temp vs 10 GiB here — see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def moe_meta(cfg: ArchConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    e, ff = cfg.moe.n_experts, cfg.moe.expert_ff
    return {
        "router": ParamMeta((d, e), F32, ("fsdp", None), "normal"),
        "wi": ParamMeta((e, d, ff), dt, (None, "fsdp", "tp"), "normal"),
        "wg": ParamMeta((e, d, ff), dt, (None, "fsdp", "tp"), "normal"),
        "wo": ParamMeta((e, ff, d), dt, (None, "tp", "fsdp"), "normal"),
    }


def _moe_local(router, wi, wg, wo, cfg: ArchConfig, xt, capacity: int):
    """Dispatch + expert FFN over a token set, no collectives.

    router (d, e); wi/wg (e, d, F); wo (e, F, d); xt (T, d).
    Returns (out (T, d) — partial if F is a TP shard — probs, gate_idx).
    """
    T, d = xt.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    n = T * k

    logits = jnp.einsum("td,de->te", xt.astype(F32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                    # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # sort (expert, phase): position within expert = sorted rank - seg start.
    # Slots are granted in token (phase) order — the graph engine's
    # deterministic combining discipline, so drops are identical on every
    # host with no coordination.
    eid = gate_idx.reshape(n)
    phase = jnp.arange(n, dtype=jnp.int32)
    order = jnp.lexsort((phase, eid))                                # (n,)
    eid_sorted = eid[order]
    rank = jnp.arange(n, dtype=jnp.int32)
    seg_start = jnp.searchsorted(eid_sorted, jnp.arange(e, dtype=eid_sorted.dtype))
    pos_sorted = rank - seg_start[eid_sorted].astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < capacity

    tgt = jnp.where(keep, eid * capacity + pos, e * capacity)        # oob = drop
    src_tok = jnp.arange(n, dtype=jnp.int32) // k
    tgt = tgt.reshape(T, k)
    keep = keep.reshape(T, k)

    # inverted dispatch: scatter token *indices* (int32 — bytes, not rows),
    # then one row gather builds the expert buffer.  No (T·k, d) tensor ever
    # exists, and the gather's backward is a single scatter-add.
    slot_tok = jnp.full((e * capacity,), T, jnp.int32)               # T -> zero row
    slot_tok = slot_tok.at[tgt.reshape(n)].set(src_tok, mode="drop")
    xtp = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    buf = xtp[slot_tok].reshape(e, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    h = jax.nn.silu(g.astype(F32)).astype(h.dtype) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo).reshape(e * capacity, d)

    # combine: every slot belongs to exactly one (token, top-k) pair, so the
    # gate weight lives on the slot and one scatter-add per MoE layer maps
    # slots back to tokens (backward = one gather; no (T·k, d) cotangents).
    slot_w = jnp.zeros((e * capacity,), F32)
    slot_w = slot_w.at[tgt.reshape(n)].set(
        (gate_vals * keep).reshape(n), mode="drop"
    )
    weighted = out_buf * slot_w[:, None].astype(out_buf.dtype)
    # bf16 accumulation is safe here: each token row sums at most top_k slot
    # rows — and it keeps the scatter-add cotangent chain out of f32.
    out = jnp.zeros((T + 1, d), xt.dtype)
    out = out.at[slot_tok].add(weighted)
    return out[:T], probs, gate_idx


def _moe_aux(probs, gate_idx, e):
    """Switch load-balancing loss from (possibly local) routing stats."""
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(gate_idx[:, 0], e, dtype=F32).mean(axis=0)
    return e * jnp.sum(me * ce)


def moe_apply_shardmap(p, cfg: ArchConfig, x, *, dp_axes=("data",),
                       capacity: Optional[int] = None):
    """Production MoE: token-local dispatch per data shard (see header)."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    fsdp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    mesh = jax.sharding.get_abstract_mesh()
    if mesh.empty:  # `with mesh:` context manager path
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    T_local = (B // n_dp) * S
    cap = capacity or max(int(cfg.moe.capacity_factor * k * T_local / e), 1)

    def body(xb, router, wi, wg, wo):
        # gather the FSDP shards of the expert weights (per layer, inside
        # the remat scope — re-gathered on the backward pass)
        router = jax.lax.all_gather(router, dp_axes, axis=0, tiled=True)
        wi = jax.lax.all_gather(wi, dp_axes, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, dp_axes, axis=2, tiled=True)

        Bl, Sl, dl = xb.shape
        out, probs, gate_idx = _moe_local(
            router, wi, wg, wo, cfg, xb.reshape(Bl * Sl, dl), cap
        )
        # complete the TP contraction and average the aux stats
        out = jax.lax.psum(out.astype(F32), "model").astype(xb.dtype)
        aux = _moe_aux(probs, gate_idx, e)
        aux = jax.lax.pmean(aux, dp_axes)
        return out.reshape(Bl, Sl, dl), aux

    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp_axes, None, None),
            P(fsdp, None),
            P(None, fsdp, "model"),
            P(None, fsdp, "model"),
            P(None, "model", fsdp),
        ),
        out_specs=(P(dp_axes, None, None), P()),
    )
    return fn(x, p["router"], p["wi"], p["wg"], p["wo"])


def moe_apply(p, cfg: ArchConfig, x, *, capacity: Optional[int] = None):
    """Global-dispatch MoE (single-device / oracle path).

    Token->expert assignment is a batched add-edge workload resolved exactly
    like the graph engine resolves conflicting ops (DESIGN.md §3): sort the
    (expert, phase) pairs, a segmented position count grants capacity slots
    in phase (= token) order, losers are dropped deterministically.
    Returns (out, aux_loss).
    """
    B, S, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    T = B * S
    if capacity is None:
        capacity = int(cfg.moe.capacity_factor * k * T / e) or 1
    out, probs, gate_idx = _moe_local(
        p["router"], p["wi"], p["wg"], p["wo"], cfg, x.reshape(T, d), capacity
    )
    return out.reshape(B, S, d), _moe_aux(probs, gate_idx, e)
