"""Architecture configuration for the assigned model families."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int            # per-expert hidden width
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 64           # N (mamba2) / head K dim (rwkv6)
    head_dim: int = 64        # P per head
    conv: int = 4             # causal conv width (mamba2)
    decay_lora: int = 64      # low-rank width of the data-dependent decay (rwkv6)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None     # default d_model // n_heads
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_bias: bool = False
    act: str = "swiglu"              # swiglu | gelu
    mlp_bias: bool = False
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    window: Optional[int] = None     # sliding-window attention
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): mamba stack with a shared attention block
    shared_attn_every: Optional[int] = None
    # vlm (llama-3.2-vision-style): cross-attention to image tokens
    xattn_every: Optional[int] = None
    n_img_tokens: int = 4096
    # audio (musicgen-style): multi-codebook token streams
    n_codebooks: int = 1
    # numerics
    dtype: str = "bfloat16"          # parameter/activation dtype
    # which layer kinds make up the stack; derived in __post_init__-style
    max_seq: int = 8192              # positional table cap (abs-pos archs)

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode: constant-size or windowed state."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)


def reduced_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Same family, tiny dims: one fwd/train step must run on CPU."""
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            expert_ff=64,
            capacity_factor=2.0,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(state=16, head_dim=16, conv=4, decay_lora=8)
    return cfg.scaled(
        n_layers=(
            min(cfg.n_layers, 4)
            if cfg.shared_attn_every is None and cfg.xattn_every is None
            else 6
        ),
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_head=32 if cfg.n_heads else None,
        d_ff=256,
        vocab=512,
        window=min(cfg.window, 32) if cfg.window else None,
        moe=moe,
        ssm=ssm,
        shared_attn_every=3 if cfg.shared_attn_every else None,
        xattn_every=3 if cfg.xattn_every else None,
        n_img_tokens=16,
        max_seq=128,
        dtype="float32",
    )
