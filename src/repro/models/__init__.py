from .config import ArchConfig, MoEConfig, SSMConfig, reduced_for_smoke
from .lm import LM

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "reduced_for_smoke", "LM"]
