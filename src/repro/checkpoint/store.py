"""Fault-tolerant checkpointing.

Contract:
  * **atomic** — a checkpoint is a directory written under a temp name and
    renamed into place; the manifest is written last, so a crash mid-write
    can never leave a checkpoint that ``latest_step`` would pick up;
  * **async** — ``save_async`` snapshots device arrays to host memory
    synchronously (cheap) and does the disk I/O on a background thread, so
    the train loop resumes immediately; ``wait()`` joins before the next
    save or on exit;
  * **mesh-agnostic / elastic** — arrays are stored logically-complete
    (gathered); ``restore`` re-shards onto whatever sharding tree the caller
    provides, so a run saved on mesh (2,2) restores bit-exactly on (4,1) or
    (1,4) (tested in tests/test_fault_tolerance.py).  At real scale the
    gather becomes a per-shard write keyed by logical coordinates — same
    layout contract, different I/O path;
  * **self-validating** — every payload file carries a checksum in the
    manifest; ``latest_step`` skips corrupt/partial checkpoints (simulated
    node failure mid-write in the tests).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        # copy=True is load-bearing: np.asarray can return a VIEW of the
        # device buffer, and the train loop donates params/opt — the next
        # step would overwrite the buffer while the async writer is still
        # serializing it (observed as a flaky kill/resume mismatch).
        flat[key] = np.array(leaf, copy=True)
    return flat


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- discovery -----------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and self._valid(os.path.join(self.dir, name)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def _valid(self, path: str) -> bool:
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            return False
        try:
            with open(mpath) as f:
                manifest = json.load(f)
            for fname, digest in manifest["checksums"].items():
                fpath = os.path.join(path, fname)
                if not os.path.exists(fpath):
                    return False
                with open(fpath, "rb") as f:
                    if hashlib.sha256(f.read()).hexdigest() != digest:
                        return False
            return True
        except (json.JSONDecodeError, KeyError, OSError):
            return False

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        self.wait()
        flat = _flatten(tree)  # device->host gather happens here
        self._write(step, flat, extra or {})

    def save_async(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        self.wait()
        flat = _flatten(tree)  # snapshot now; I/O in background
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=self.dir)
        try:
            payload = os.path.join(tmp, "arrays.npz")
            np.savez(payload, **flat)
            with open(payload, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest = {
                "step": step,
                "extra": extra,
                "checksums": {"arrays.npz": digest},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; optionally re-shard
        with a matching tree of ``jax.sharding.Sharding`` (elastic resume)."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            flat = {k: data[k] for k in data.files}

        paths, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        leaves = []
        for path_elems, like in paths:
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path_elems
            )
            arr = flat[key]
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            tree = jax.tree.map(
                lambda a, l: jax.numpy.asarray(a, dtype=l.dtype), tree, like_tree
            )
        return tree

    def extra(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step:010d}", "manifest.json")
        with open(path) as f:
            return json.load(f)["extra"]
