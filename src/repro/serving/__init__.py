from .paged_cache import PagedKVManager
from .engine import ServingEngine, Request

__all__ = ["PagedKVManager", "ServingEngine", "Request"]
