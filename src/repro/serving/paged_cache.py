"""Paged-KV page tables managed by the wait-free graph engine.

This is where the paper's technique is a first-class production feature:
the dynamic (sequence → page) ownership structure *is* a concurrent directed
graph, mutated by batches of operations:

  admission   -> AddVertex(seq)  + AddEdge(seq, page) per initial page
  growth      -> AddEdge(seq, page) when a sequence crosses a page boundary
  completion  -> RemoveVertex(seq)  — incarnation semantics make every
                 owned edge *abstractly* vanish at once (the paper's Fig. 3
                 mechanism doing real work: a later re-use of the same seq id
                 can never resurrect stale page ownership)
  validation  -> ContainsEdge(seq, page) before every page write

All mutations go through ``WaitFreeGraph.apply`` (fpsp engine), so the
linearization is the phase order of the op batch — identical on every host
given the same request stream.  The host-side mirrors (``seq_pages``,
``free``) are pure derivations of that deterministic history: any replica
(or a replacement after a node failure) reconstructs byte-identical tables
by replaying the op log (tested in tests/test_serving.py).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import WaitFreeGraph
from repro.core.types import (
    OP_ADD_EDGE,
    OP_ADD_VERTEX,
    OP_REMOVE_VERTEX,
)

# key-space split: sequence vertices get ids >= PAGE_KEYS
PAGE_KEYS = 1 << 20


class PagedKVManager:
    def __init__(self, num_pages: int, page_size: int, mode: str = "fpsp"):
        self.num_pages = num_pages
        self.page_size = page_size
        def pow2(n: int) -> int:
            p = 1
            while p < n:
                p *= 2
            return p

        self.graph = WaitFreeGraph(
            v_capacity=pow2(max(64, 2 * num_pages)),
            e_capacity=pow2(max(256, 4 * num_pages)),
            mode=mode,
        )
        # page vertices exist for the lifetime of the cache
        ops = [OP_ADD_VERTEX] * num_pages
        us = list(range(num_pages))
        ok = self.graph.apply(ops, us, us)
        assert all(ok), "page vertex init failed"
        self.free: List[int] = list(range(num_pages - 1, -1, -1))  # pop order
        self.seq_pages: Dict[int, List[int]] = {}
        self.seq_len: Dict[int, int] = {}
        self.op_log: List[Tuple[list, list, list]] = []

    # -- op-batch construction (one batch per serving step) ------------------
    def step_ops(
        self,
        admit: Dict[int, int],      # seq_id -> prompt length (tokens)
        extend: List[int],          # seq_ids that produced one more token
        finish: List[int],          # seq_ids completed this step
    ):
        """Build + apply one deterministic op batch; returns per-seq new pages."""
        ops, us, vs = [], [], []
        plan: List[Tuple[str, int, Optional[int]]] = []

        for seq in sorted(admit):
            ops.append(OP_ADD_VERTEX)
            us.append(PAGE_KEYS + seq)
            vs.append(0)
            plan.append(("admit", seq, None))
            n_pages = -(-admit[seq] // self.page_size)
            for _ in range(max(n_pages, 1)):
                page = self._pop_free()
                ops.append(OP_ADD_EDGE)
                us.append(PAGE_KEYS + seq)
                vs.append(page)
                plan.append(("own", seq, page))

        for seq in extend:
            new_len = self.seq_len[seq] + 1
            if (new_len - 1) // self.page_size != (self.seq_len[seq] - 1) // self.page_size:
                page = self._pop_free()
                ops.append(OP_ADD_EDGE)
                us.append(PAGE_KEYS + seq)
                vs.append(page)
                plan.append(("own", seq, page))
            plan.append(("len", seq, None))

        for seq in finish:
            ops.append(OP_REMOVE_VERTEX)
            us.append(PAGE_KEYS + seq)
            vs.append(0)
            plan.append(("finish", seq, None))

        results = self.graph.apply(ops, us, vs) if ops else np.zeros((0,), bool)
        self.op_log.append((list(ops), list(us), list(vs)))

        # fold results back into the mirrors, in plan order
        ri = 0
        new_pages: Dict[int, List[int]] = {}
        for kind, seq, page in plan:
            if kind == "admit":
                assert bool(results[ri]), f"admit {seq}: vertex add failed"
                ri += 1
                self.seq_pages[seq] = []
                self.seq_len[seq] = 0
            elif kind == "own":
                assert bool(results[ri]), f"page grant {page} -> {seq} failed"
                ri += 1
                self.seq_pages[seq].append(page)
                new_pages.setdefault(seq, []).append(page)
            elif kind == "len":
                self.seq_len[seq] += 1
            elif kind == "finish":
                assert bool(results[ri]), f"finish {seq}: vertex remove failed"
                ri += 1
                for p in self.seq_pages.pop(seq):
                    self.free.append(p)
                self.seq_len.pop(seq)
        for seq, n in admit.items():
            self.seq_len[seq] = n
        return new_pages

    def _pop_free(self) -> int:
        if not self.free:
            raise RuntimeError("out of KV pages")
        return self.free.pop()

    # -- queries ----------------------------------------------------------------
    def block_table(self, seqs: List[int], pages_per_seq: int) -> np.ndarray:
        bt = np.zeros((len(seqs), pages_per_seq), np.int32)
        for i, s in enumerate(seqs):
            pages = self.seq_pages.get(s, [])
            assert len(pages) <= pages_per_seq, (s, len(pages))
            bt[i, : len(pages)] = pages
        return bt

    def owns(self, seq: int, page: int) -> bool:
        """Validated through the graph (the paper's ContainsEdge)."""
        return self.graph.contains_edge(PAGE_KEYS + seq, page)

    def replay(self) -> "PagedKVManager":
        """Reconstruct a fresh manager from the deterministic op log —
        the straggler/failover path: a replacement host reaches the same
        graph state *and* the same ordered page tables with no coordination,
        because edge grants appear in the log in phase order."""
        twin = PagedKVManager(self.num_pages, self.page_size)
        for ops, us, vs in self.op_log:
            if not ops:
                continue
            results = twin.graph.apply(ops, us, vs)
            for op, u, v, ok in zip(ops, us, vs, results):
                if op == OP_ADD_VERTEX and u >= PAGE_KEYS and ok:
                    twin.seq_pages[u - PAGE_KEYS] = []
                elif op == OP_ADD_EDGE and ok:
                    seq = u - PAGE_KEYS
                    twin.seq_pages[seq].append(v)
                    if v in twin.free:
                        twin.free.remove(v)
                elif op == OP_REMOVE_VERTEX and u >= PAGE_KEYS and ok:
                    for p in twin.seq_pages.pop(u - PAGE_KEYS, []):
                        twin.free.append(p)
            twin.op_log.append((list(ops), list(us), list(vs)))
        return twin
