"""Continuous-batching serving engine over the wait-free page table.

Production shape (vLLM-style), CPU-runnable at smoke scale:

  * **slot-based continuous batching** — ``max_batch`` cache slots step
    together every engine tick; per-request asynchrony comes from *forced
    tokens*: a slot still consuming its prompt feeds the next prompt token
    (logits ignored), a generating slot feeds its last sampled token.  One
    ``decode_step`` per tick serves admission, prefill and decode at once —
    there is no separate prefill graph to compile or schedule.
  * **slot reuse** — admitting into a previously used slot zeroes that
    slot's KV rows / recurrent state and sets ``cache["start"][slot]`` so
    attention never sees the predecessor's rows (layers._decode_attention).
  * **wait-free page accounting** — every tick builds one op batch
    (admit/extend/finish) for :class:`PagedKVManager`; the paper's graph is
    the source of truth for page ownership, and its deterministic phase
    order is what makes ``failover()`` exact.
  * **straggler/failover** — ``failover()`` replays the op log into a fresh
    manager (a replacement host) and verifies page tables match; sampling is
    seeded per (request, position), so a replacement host regenerates
    byte-identical tokens too.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM
from repro.models.config import ArchConfig
from repro.obs import metrics as obsm
from repro.serving.paged_cache import PagedKVManager


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (P,) int32 (or (P, ncb))
    max_new_tokens: int = 16
    temperature: float = 0.0                # 0 = greedy
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    submit_tick: int = -1                   # stamped by ServingEngine.submit


class ServingEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        max_batch: int = 4,
        max_len: int = 128,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        seed: int = 0,
        obs=None,
    ):
        self.cfg = cfg
        # telemetry registry (see docs/OBSERVABILITY.md): None → REPRO_OBS
        # env, True → fresh Registry, False → no-op.  Purely additive —
        # admission order, sampling, and page accounting are unchanged.
        self.obs = obsm.resolve(obs)
        self.model = LM(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.page_size = page_size
        num_pages = num_pages or (max_batch * max_len) // page_size
        self.pages = PagedKVManager(num_pages, page_size)
        self.seed = seed

        self.cache = self.model.decode_init(max_batch, max_len, params=params)
        self.cache["start"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._consumed: List[int] = [0] * max_batch  # prompt tokens fed
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.ticks = 0
        self._step = jax.jit(self._decode_fn())

    def _decode_fn(self):
        model, cfg = self.model, self.cfg

        def fn(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        return fn

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.ndim >= 1 and len(req.prompt) >= 1
        assert len(req.prompt) + req.max_new_tokens <= self.max_len
        req.submit_tick = self.ticks
        self.obs.counter("serving.submitted")
        self.queue.append(req)

    def run(self, max_ticks: int = 10_000) -> Dict[int, Request]:
        while (self.queue or any(s is not None for s in self.slots)):
            self.tick()
            if self.ticks >= max_ticks:
                raise RuntimeError("serving did not drain")
        return self.finished

    # -- one engine tick -----------------------------------------------------
    def tick(self) -> None:
        reg = self.obs
        if reg.enabled:
            reg.hist("serving.queue_depth", len(self.queue))
            reg.gauge(
                "serving.active_slots",
                sum(1 for s in self.slots if s is not None),
            )
        with reg.span("serving.tick"):
            self._tick()

    def _tick(self) -> None:
        pos = int(self.cache["len"])
        # timeline compaction: the shared position axis only grows; once every
        # slot is idle, restart it so long request streams drain on a bounded
        # cache (the paged manager keeps its own state — page ownership is
        # per-request, not per-position).
        if pos > 0 and self.queue and all(s is None for s in self.slots):
            self.cache = self.model.decode_init(
                self.max_batch, self.max_len, params=self.params
            )
            self.cache["start"] = jnp.zeros((self.max_batch,), jnp.int32)
            pos = 0
        admit: Dict[int, int] = {}
        extend: List[int] = []
        finish: List[int] = []

        # admission: fill free slots while page budget + timeline room allow
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = len(req.prompt) + req.max_new_tokens
            pages_needed = -(-need // self.page_size)
            if pos + need > self.max_len or len(self.pages.free) < pages_needed:
                break  # deterministic: head-of-line blocking, no reorder
            self.queue.pop(0)
            self._admit(slot, req, pos)
            admit[req.id] = len(req.prompt)
            if self.obs.enabled and req.submit_tick >= 0:
                # admission latency in engine ticks (deterministic, unlike
                # wall clock): how long the request sat head-of-line
                self.obs.hist(
                    "serving.admission_wait_ticks", self.ticks - req.submit_tick
                )

        # build this tick's forced/sampled token per active slot
        tok_shape = (
            (self.max_batch, 1)
            if self.cfg.n_codebooks == 1
            else (self.max_batch, 1, self.cfg.n_codebooks)
        )
        tokens = np.zeros(tok_shape, np.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            c = self._consumed[slot]
            if c < len(req.prompt):
                tokens[slot, 0] = req.prompt[c]
            else:
                tokens[slot, 0] = req.generated[-1]

        active = [s for s in self.slots if s is not None]
        if not active and not admit:
            return

        logits, self.cache = self._step(
            self.params, jnp.asarray(tokens), self.cache
        )
        logits = np.asarray(logits[:, -1], np.float32)

        # fold logits back: sample where the prompt is exhausted
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self._consumed[slot] += 1
            c = self._consumed[slot]
            if c >= len(req.prompt):
                nxt = self._sample(req, logits[slot], position=c)
                req.generated.append(nxt)
                if len(req.generated) >= req.max_new_tokens:
                    req.done = True
                    finish.append(req.id)
                    self.finished[req.id] = req
                    self.slots[slot] = None
                    self.obs.counter("serving.finished")
                else:
                    extend.append(req.id)

        # one deterministic page-table op batch per tick (the paper at work)
        self.pages.step_ops(admit, extend, finish)
        self.ticks += 1

    # -- internals -------------------------------------------------------------
    def _admit(self, slot: int, req: Request, pos: int) -> None:
        self.slots[slot] = req
        self._consumed[slot] = 0
        # zero the slot's stale cache rows + mark admission offset
        def reset(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.max_batch:
                return leaf.at[:, slot].set(0)
            return leaf
        for key in ("kv", "shared_kv", "states"):
            if key in self.cache:
                self.cache[key] = jax.tree.map(reset, self.cache[key])
        self.cache["start"] = self.cache["start"].at[slot].set(pos)

    def _sample(self, req: Request, logits_row: np.ndarray, position: int) -> int:
        if self.cfg.n_codebooks > 1:
            logits_row = logits_row[0]  # first codebook drives the id stream
        logits_row = logits_row[: self.cfg.vocab]
        if req.temperature <= 0.0:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, req.id, position])
        )
        z = logits_row / req.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(rng.choice(len(p), p=p))

    # -- fault tolerance ---------------------------------------------------------
    def failover(self) -> PagedKVManager:
        """Replacement-host path: rebuild page tables from the op log and
        verify the twin matches (deterministic phase order ⇒ exact)."""
        twin = self.pages.replay()
        assert twin.seq_pages == self.pages.seq_pages, "failover mismatch"
        assert sorted(twin.free) == sorted(self.pages.free)
        return twin
