"""Probe-chain health: post-hoc histograms over the open-addressing tables.

The engines never materialize per-key probe lengths (locate is a fixed
``MAX_PROBES``-bounded ``fori_loop``), but the length is *recoverable* from
the final layout: a key in slot ``s`` with home slot ``h`` sits at the
unique triangular-probe step ``t < MAX_PROBES`` with
``(h + t*(t+1)//2) & (cap-1) == s``.  Deriving the histogram from the
tables after the fact keeps the jitted programs untouched — the obs
bit-identity contract (see :mod:`repro.obs`).

Two flavours, with different invariance guarantees (pinned by
``tests/test_obs.py``):

* **physical** (:func:`table_probe_histogram`) — the per-shard tables as the
  device probes them.  Invariant across ``maintenance_impl`` (all rehash
  impls build bit-identical tables) but **not** across shard counts: each
  shard hashes its partition into a private slot space.
* **canonical** (:func:`directory_probe_histogram`) — the global
  :class:`~repro.core.sharding.VertexDirectory`, whose placement depends
  only on the live key set.  Invariant across ``n_shards`` by construction.

Probe length is 1-based: ``1`` = key found at its home slot.
"""

from __future__ import annotations

from typing import Dict, Sequence, Union

import numpy as np

from ..core.hashing import edge_hash32_np, vertex_hash32_np
from ..core.types import EMPTY_KEY, MAX_PROBES, GraphState


def _probe_lengths(home: np.ndarray, slot: np.ndarray, cap: int) -> np.ndarray:
    """1-based triangular-probe chain length of each occupied slot."""
    steps = np.arange(MAX_PROBES, dtype=np.int64)
    offs = (steps * (steps + 1)) // 2
    cand = (home.astype(np.int64)[:, None] + offs[None, :]) & (cap - 1)
    hit = cand == slot.astype(np.int64)[:, None]
    # every placed key is on its own chain within MAX_PROBES (the locate
    # bound) — argmax finds the first (unique-by-construction) hit
    return np.argmax(hit, axis=1).astype(np.int64) + 1


def _hist(lengths: np.ndarray) -> Dict[int, int]:
    vals, counts = np.unique(lengths, return_counts=True)
    return {int(v): int(c) for v, c in zip(vals, counts)}


def _merge(into: Dict[int, int], other: Dict[int, int]) -> Dict[int, int]:
    for k, v in other.items():
        into[k] = into.get(k, 0) + v
    return into


def _vertex_lengths(state: GraphState) -> np.ndarray:
    keys = np.asarray(state.v_key)
    occ = keys != EMPTY_KEY
    cap = keys.shape[0]
    slot = np.flatnonzero(occ)
    home = (vertex_hash32_np(keys[occ]) & np.uint32(cap - 1)).astype(np.int64)
    return _probe_lengths(home, slot, cap)


def _edge_lengths(state: GraphState) -> np.ndarray:
    ku = np.asarray(state.e_key_u)
    kv = np.asarray(state.e_key_v)
    occ = ku != EMPTY_KEY
    cap = ku.shape[0]
    slot = np.flatnonzero(occ)
    home = (edge_hash32_np(ku[occ], kv[occ]) & np.uint32(cap - 1)).astype(np.int64)
    return _probe_lengths(home, slot, cap)


def _as_states(graph_or_states) -> Sequence[GraphState]:
    if isinstance(graph_or_states, GraphState):
        return (graph_or_states,)
    if hasattr(graph_or_states, "n_shards"):  # a WaitFreeGraph
        g = graph_or_states
        return tuple(g.shards) if g.n_shards > 1 else (g.state,)
    return tuple(graph_or_states)


def table_probe_histogram(
    graph_or_states,
) -> Dict[str, Dict[int, int]]:
    """Physical probe-length histograms (``{"vertex": {len: count},
    "edge": ...}``) over one state, a shard list, or a ``WaitFreeGraph``
    (summed across shards).  Occupied slots only — tombstones included,
    since the device probes past them too."""
    v_hist: Dict[int, int] = {}
    e_hist: Dict[int, int] = {}
    for st in _as_states(graph_or_states):
        _merge(v_hist, _hist(_vertex_lengths(st)))
        _merge(e_hist, _hist(_edge_lengths(st)))
    return {"vertex": v_hist, "edge": e_hist}


def directory_probe_histogram(graph_or_states) -> Dict[int, int]:
    """Probe-length histogram of the canonical global vertex directory —
    deterministic in the live key set alone, hence identical for any
    ``n_shards`` holding the same abstract graph."""
    # lazy import: sharding imports maintenance/traversal — pulling those in
    # at module-import time would drag jax program construction into every
    # obs consumer (and risks cycles during repro.core partial init)
    from ..core.sharding import build_vertex_directory

    d = build_vertex_directory(_as_states(graph_or_states))
    cap = d.v_key.shape[0]
    home = (vertex_hash32_np(d.sorted_key) & np.uint32(cap - 1)).astype(np.int64)
    return _hist(_probe_lengths(home, d.sorted_slot.astype(np.int64), cap))


def mean_probe_len(graph_or_states) -> Union[float, None]:
    """Mean physical probe-chain length across both tables (vertex + edge,
    all shards) — the benchmark's ``mean_probe_len`` column.  ``None`` for
    empty tables."""
    h = table_probe_histogram(graph_or_states)
    total = sum(l * c for part in h.values() for l, c in part.items())
    n = sum(c for part in h.values() for c in part.values())
    return (total / n) if n else None


def record(reg, graph_or_states) -> Dict[str, Dict[int, int]]:
    """Record the physical histograms into ``reg`` (``probe.vertex`` /
    ``probe.edge`` exact-integer histograms) and return them."""
    h = table_probe_histogram(graph_or_states)
    for name, part in (("probe.vertex", h["vertex"]), ("probe.edge", h["edge"])):
        for length, count in sorted(part.items()):
            reg.hist(name, [length] * count)
    return h
