"""repro.obs — wait-free telemetry: metrics, spans, and probe health.

The paper's performance argument is statistical — the FPSP slow path is
*rare* (§3.4), helping rounds are *bounded*, the hash table stays *healthy*
— and this package is how the repro measures those claims at runtime
instead of inferring them from wall clock.  Two halves:

* :mod:`repro.obs.metrics` — a thread-safe registry of counters, gauges,
  integer histograms, float samples, context-manager spans (wall-clock
  timing) and bounded structured events, plus the no-op twin every code
  path holds when observability is off.  Enable via
  ``WaitFreeGraph(obs=...)`` or the ``REPRO_OBS`` environment variable.
* :mod:`repro.obs.probes` — post-hoc probe-chain health derivations over
  the hash tables (physical per-table histograms, the shard-count-invariant
  canonical-directory histogram).

**Overhead contract** (the bit-identity discipline): every metric is
derived from arrays the jitted programs already compute — stats vectors,
conflict masks, claim-round counters, BFS level maps — via small
post-device host reductions.  Enabling observability never changes a jitted
program, so obs-on and obs-off runs produce byte-identical graph states and
query answers (pinned by ``tests/test_obs.py``).  When disabled, every
recording call is a method on the shared no-op registry: no locks, no
dict writes, no device syncs.

Metric catalog, span naming convention, and the ``dump()`` JSON schema:
``docs/OBSERVABILITY.md``.
"""

from .metrics import (
    NOOP,
    NoopRegistry,
    Registry,
    active,
    counter,
    event,
    fastpath_frac,
    from_env,
    gauge,
    hist,
    observe,
    resolve,
    span,
    use,
)

__all__ = [
    "Registry",
    "NoopRegistry",
    "NOOP",
    "active",
    "use",
    "resolve",
    "from_env",
    "counter",
    "gauge",
    "hist",
    "observe",
    "event",
    "span",
    "fastpath_frac",
]
