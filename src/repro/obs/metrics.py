"""Thread-safe metrics registry + no-op twin + active-registry context.

Design constraints (see ``docs/OBSERVABILITY.md`` for the full contract):

* **Derived, never intrusive** — recorders take values the jitted programs
  already computed (stats vectors, mask sums, level maps).  Nothing in this
  module touches a device array; callers reduce on the host and pass plain
  ints/floats.  That is what makes the obs-on/obs-off bit-identity pin of
  ``tests/test_obs.py`` possible.
* **Zero-cost off switch** — disabled code paths hold :data:`NOOP`, whose
  methods are empty and whose ``span`` returns one shared null context
  manager.  No locks, no allocation, no branching beyond the call itself.
* **Exact integer histograms** — claim rounds, probe lengths, queue depths
  and frontier depths are small ints; the histogram stores exact per-value
  counts (not bucketed approximations), so determinism tests can compare
  histograms across shard counts and maintenance impls with ``==``.
* **Ambient access without parameter threading** — module-level code
  (maintenance claim rounds, the traversal delta-fold decisions) records
  through the thread-local *active* registry installed by
  :func:`use`; ``WaitFreeGraph`` wraps every public entry point in
  ``use(self.obs)`` so nested layers attach to the right run.
"""

from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import Dict, Iterable, List, Optional, Union

_MAX_EVENTS = 1024  # bounded event log: growth/rehash escalations are rare

_TRUTHY = ("1", "true", "on", "yes")


def _summary_ms(samples: List[float]) -> Dict[str, float]:
    """count/total/mean/p50/p99/max over a duration list, in milliseconds."""
    n = len(samples)
    s = sorted(samples)
    total = sum(s)
    return {
        "count": n,
        "total_ms": 1e3 * total,
        "mean_ms": 1e3 * total / n,
        "p50_ms": 1e3 * s[n // 2],
        "p99_ms": 1e3 * s[min(n - 1, (99 * n) // 100)],
        "max_ms": 1e3 * s[-1],
    }


def _hist_summary(counts: Dict[int, int]) -> Dict[str, object]:
    values = sorted(counts)
    n = sum(counts.values())
    total = sum(v * c for v, c in counts.items())
    out = {
        "count": n,
        "total": total,
        "mean": total / n,
        "min": values[0],
        "max": values[-1],
        "p50": _percentile_from_counts(counts, 50.0),
        "p99": _percentile_from_counts(counts, 99.0),
        "counts": {str(v): counts[v] for v in values},
    }
    return out


def _percentile_from_counts(counts: Dict[int, int], q: float) -> int:
    n = sum(counts.values())
    rank = min(n - 1, int((q / 100.0) * n))
    seen = 0
    for v in sorted(counts):
        seen += counts[v]
        if seen > rank:
            return v
    return max(counts)  # unreachable for well-formed counts


class _Span:
    """Context manager timing one named section into a registry."""

    __slots__ = ("_reg", "_name", "_t0")

    def __init__(self, reg: "Registry", name: str):
        self._reg = reg
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._reg._record_span(self._name, time.perf_counter() - self._t0)
        return False


class Registry:
    """Thread-safe store of counters, gauges, histograms, samples, spans,
    and bounded events.  One registry per observed run (a graph, a serving
    engine, a benchmark build); :meth:`dump` snapshots it as JSON-ready
    plain data."""

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[int, int]] = {}
        self._samples: Dict[str, List[float]] = {}
        self._spans: Dict[str, List[float]] = {}
        self._events: List[Dict] = []
        self._dropped_events = 0

    # -- recorders ---------------------------------------------------------
    def counter(self, name: str, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + int(n)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def hist(self, name: str, values: Union[int, Iterable[int]]) -> None:
        """Record exact integer observation(s) into a named histogram."""
        if not isinstance(values, Iterable):
            values = (values,)
        with self._lock:
            h = self._hists.setdefault(name, {})
            for v in values:
                v = int(v)
                h[v] = h.get(v, 0) + 1

    def observe(self, name: str, value: float) -> None:
        """Record one float sample (e.g. a latency in ms) for percentiles."""
        with self._lock:
            self._samples.setdefault(name, []).append(float(value))

    def event(self, name: str, **fields) -> None:
        """Append one structured event (growth, rehash escalation, ...)."""
        with self._lock:
            if len(self._events) >= _MAX_EVENTS:
                self._dropped_events += 1
                return
            self._events.append({"event": name, **fields})

    def span(self, name: str) -> _Span:
        """``with reg.span("phase.route"): ...`` — wall-clock section timer."""
        return _Span(self, name)

    def _record_span(self, name: str, seconds: float) -> None:
        with self._lock:
            self._spans.setdefault(name, []).append(seconds)

    # -- readers -----------------------------------------------------------
    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def hist_counts(self, name: str) -> Dict[int, int]:
        with self._lock:
            return dict(self._hists.get(name, {}))

    def percentile(self, name: str, q: float) -> Optional[float]:
        """q-th percentile of a histogram (exact) or sample series, or
        ``None`` when the name has no observations."""
        with self._lock:
            h = self._hists.get(name)
            if h:
                return float(_percentile_from_counts(dict(h), q))
            s = self._samples.get(name)
            if s:
                ss = sorted(s)
                return ss[min(len(ss) - 1, int((q / 100.0) * len(ss)))]
        return None

    def dump(self) -> Dict:
        """Structured JSON-ready snapshot (schema: ``docs/OBSERVABILITY.md``)."""
        with self._lock:
            out = {
                "schema": "repro-obs/1",
                "counters": dict(sorted(self._counters.items())),
                "gauges": dict(sorted(self._gauges.items())),
                "histograms": {
                    k: _hist_summary(v)
                    for k, v in sorted(self._hists.items())
                    if v
                },
                "samples": {
                    k: _summary_ms([x / 1e3 for x in v])  # values already ms
                    for k, v in sorted(self._samples.items())
                    if v
                },
                "spans": {
                    k: _summary_ms(v) for k, v in sorted(self._spans.items()) if v
                },
                "events": list(self._events),
            }
            if self._dropped_events:
                out["dropped_events"] = self._dropped_events
            return out


class NoopRegistry:
    """API twin of :class:`Registry` with empty bodies — what every
    instrumented path holds when observability is disabled."""

    enabled = False
    _NULL = contextlib.nullcontext()

    def counter(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def hist(self, name, values):
        pass

    def observe(self, name, value):
        pass

    def event(self, name, **fields):
        pass

    def span(self, name):
        return self._NULL

    def counters(self):
        return {}

    def hist_counts(self, name):
        return {}

    def percentile(self, name, q):
        return None

    def dump(self):
        return {"schema": "repro-obs/1", "enabled": False}


NOOP = NoopRegistry()


def from_env() -> Union[Registry, NoopRegistry]:
    """A fresh :class:`Registry` when ``REPRO_OBS`` is truthy, else NOOP."""
    if os.environ.get("REPRO_OBS", "").strip().lower() in _TRUTHY:
        return Registry()
    return NOOP


def resolve(obs) -> Union[Registry, NoopRegistry]:
    """Normalize the ``obs=`` constructor flag: ``None`` defers to the
    ``REPRO_OBS`` env var, ``True``/``False`` force a fresh registry / the
    no-op, and a registry instance is used as-is (sharing one registry
    across graphs aggregates their metrics)."""
    if obs is None:
        return from_env()
    if obs is True:
        return Registry()
    if obs is False:
        return NOOP
    return obs


# ---------------------------------------------------------------------------
# thread-local active registry: ambient recording for module-level code
# ---------------------------------------------------------------------------

_tls = threading.local()


def active() -> Union[Registry, NoopRegistry]:
    """The innermost registry installed by :func:`use` on this thread
    (NOOP outside any ``use`` block)."""
    stack = getattr(_tls, "stack", None)
    return stack[-1] if stack else NOOP


@contextlib.contextmanager
def use(reg):
    """Install ``reg`` as the thread's active registry for the block —
    how ``WaitFreeGraph`` hands its registry to maintenance/traversal code
    without threading a parameter through every signature."""
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    stack.append(reg if reg is not None else NOOP)
    try:
        yield stack[-1]
    finally:
        stack.pop()


# module-level recorder shorthands against the active registry
def counter(name: str, n: Union[int, float] = 1) -> None:
    active().counter(name, n)


def gauge(name: str, value: float) -> None:
    active().gauge(name, value)


def hist(name: str, values) -> None:
    active().hist(name, values)


def observe(name: str, value: float) -> None:
    active().observe(name, value)


def event(name: str, **fields) -> None:
    active().event(name, **fields)


def span(name: str):
    return active().span(name)


# ---------------------------------------------------------------------------
# derived summaries
# ---------------------------------------------------------------------------


def fastpath_frac(reg) -> Optional[float]:
    """Fraction of FPSP ops resolved on the fast (sort-free) lane.

    1-shard FPSP graphs record the full conflict mask
    (``fastpath.conflicted`` / ``fastpath.ops``); partitioned graphs record
    the shard-invariant edge-lane split (``fastpath.edge_dup`` /
    ``fastpath.eops`` — duplicate ``(u, v)`` keys always co-locate on one
    shard, so the summed counters match any shard count).  Returns ``None``
    when the registry saw no FPSP traffic."""
    c = reg.counters()
    ops = c.get("fastpath.ops", 0)
    if ops:
        return 1.0 - c.get("fastpath.conflicted", 0) / ops
    eops = c.get("fastpath.eops", 0)
    if eops:
        return 1.0 - c.get("fastpath.edge_dup", 0) / eops
    return None
