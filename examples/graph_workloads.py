"""Paper §5 workloads, small-scale: all five engines on the three mixes.

    PYTHONPATH=src python examples/graph_workloads.py

A miniature of benchmarks/graph_throughput.py (the full Fig. 4 sweep) that
also cross-checks every engine's results against the sequential oracle.
"""

import numpy as np

from repro.core import baselines, engine, fastpath
from repro.core.oracle import run_sequential
from repro.core.types import make_batch, make_state
from repro.core.workloads import initial_vertices, sample_batch

ENGINES = {
    "coarse": baselines.apply_coarse,
    "serial": baselines.apply_serial,
    "lockfree": baselines.apply_lockfree,
    "waitfree": engine.apply_batch,
    "fpsp": fastpath.apply_batch_fpsp,
}

init = make_state(4096, 16384)
ops, us, vs = initial_vertices(1000)
base = engine.apply_batch(init, make_batch(ops, us, vs)).state

for mix in ("lookup", "balanced", "update"):
    rng = np.random.default_rng(7)
    ops, us, vs = sample_batch(rng, 256, mix)
    batch = make_batch(ops, us, vs)
    _, oracle = run_sequential(*initial_vertices(1000))
    expected, _ = run_sequential(ops, us, vs, graph=oracle)
    line = [f"{mix:9s}"]
    for name, fn in ENGINES.items():
        res = fn(base, batch)
        ok = np.asarray(res.success).tolist() == expected
        line.append(f"{name}={'OK' if ok else 'MISMATCH'}")
    print("  ".join(line))
print("all engines agree with the sequential oracle on every mix")
