"""Batched serving with the wait-free paged-KV manager (the paper's graph
as a production page table).

    PYTHONPATH=src python examples/serve_paged.py [--arch mixtral-8x7b]

Submits a burst of prompts, runs continuous batching to completion, then
simulates a host failure: a replacement host replays the deterministic op
log and must reconstruct byte-identical page tables.
"""

import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = LM(cfg).init(jax.random.key(0))
    eng = ServingEngine(cfg, params, max_batch=4, max_len=96, page_size=8)

    rng = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(rng.integers(4, 20))
        shape = (plen,) if cfg.n_codebooks == 1 else (plen, cfg.n_codebooks)
        eng.submit(Request(
            id=i, prompt=rng.integers(0, cfg.vocab, shape).astype(np.int32),
            max_new_tokens=8, temperature=0.7,
        ))
    done = eng.run()
    print(f"[{cfg.name}] served {len(done)} requests in {eng.ticks} ticks")
    print(f"  sample completion (req 0): {done[0].generated}")

    twin = eng.failover()
    print(f"  failover: replayed {len(eng.pages.op_log)} op batches -> "
          f"identical page tables ✓ (pages free: {len(twin.free)})")


if __name__ == "__main__":
    main()
