"""Quickstart: the paper's six-operation concurrent graph API.

    PYTHONPATH=src python examples/quickstart.py

Shows (1) the sequential convenience API, (2) a concurrent batch — the ODA —
resolved in one wait-free pass, (3) the paper's Fig. 3 subtlety: an edge op
and a concurrent remove-vertex on its endpoint, linearized by phase order.
"""

import numpy as np

from repro.core import WaitFreeGraph
from repro.core.types import (
    OP_ADD_EDGE, OP_ADD_VERTEX, OP_CONTAINS_EDGE, OP_REMOVE_VERTEX,
)

g = WaitFreeGraph(mode="fpsp")

# -- 1. the paper's API, one op at a time -----------------------------------
assert g.add_vertex(1)
assert g.add_vertex(2)
assert not g.add_vertex(1)          # duplicate -> failure (sequential spec)
assert g.add_edge(1, 2)
assert g.contains_edge(1, 2)
assert g.remove_vertex(1)
assert not g.contains_edge(1, 2)    # incident edges vanish with the vertex
print("sequential spec: OK")

# -- 2. a concurrent batch (the ODA): 1000 ops, one wait-free pass ----------
rng = np.random.default_rng(0)
n = 1000
ops = rng.choice([OP_ADD_VERTEX, OP_ADD_EDGE], size=n, p=[0.3, 0.7]).astype(np.int32)
us = rng.integers(0, 200, size=n).astype(np.int32)
vs = rng.integers(0, 200, size=n).astype(np.int32)
results = g.apply(ops, us, vs)
V, E = g.snapshot()
print(f"batch of {n} ops -> {int(results.sum())} succeeded; |V|={len(V)} |E|={len(E)}")

# -- 3. Fig. 3: edge op vs concurrent endpoint removal ----------------------
g2 = WaitFreeGraph()
g2.add_vertex(10), g2.add_vertex(20)
# one batch = concurrent ops; phase order (= batch order) linearizes them:
res = g2.apply(
    [OP_REMOVE_VERTEX, OP_ADD_EDGE, OP_CONTAINS_EDGE],
    [10, 10, 10],
    [0, 20, 20],
)
# RemoveVertex(10) at phase 0 -> AddEdge(10,20) at phase 1 must FAIL
assert res.tolist() == [True, False, False]
print("Fig. 3 consistency (edge op sees phase-ordered vertex liveness): OK")
