"""Batched wait-free reachability + snapshot queries, end to end.

    PYTHONPATH=src python examples/reachability.py

Builds a graph under the ``traversal`` mix, then answers reachability, BFS
level, and k-hop neighborhood queries — every query batch runs against one
consistent CSR snapshot of the post-batch state (linearized at the batch
boundary, like the wait-free GetPath/snapshot of arXiv 1809.00896 and
2310.02380), and every answer is cross-checked against the sequential
oracle.
"""

import numpy as np

from repro.core import SequentialGraph, WaitFreeGraph, run_sequential
from repro.core.workloads import initial_vertices, sample_batch, sample_query_pairs

KEY_SPACE = 64
rng = np.random.default_rng(7)

g = WaitFreeGraph(v_capacity=256, e_capacity=1024, mode="fpsp")
oracle = SequentialGraph()
ops, us, vs = initial_vertices(KEY_SPACE)  # the paper's pre-seeded vertices
got = g.apply(ops, us, vs)
exp, oracle = run_sequential(ops, us, vs, graph=oracle)
assert got.tolist() == exp
for _ in range(3):
    ops, us, vs = sample_batch(rng, 128, "traversal", key_space=KEY_SPACE)
    got = g.apply(ops, us, vs)
    exp, oracle = run_sequential(ops, us, vs, graph=oracle)
    assert got.tolist() == exp

V, E = g.snapshot()
assert (V, E) == (oracle.vertices, oracle.edges)
print(f"graph: {len(V)} vertices, {len(E)} edges (consistent snapshot)")

# one batch of pairwise reachability queries, one shared snapshot
us, vs = sample_query_pairs(rng, 16, KEY_SPACE)
got = g.reachable(us, vs)
for u, v, r in zip(us, vs, got):
    assert bool(r) == oracle.reachable(int(u), int(v))
print(f"reachable: {int(got.sum())}/{len(got)} of a {len(got)}-pair batch connected")

# full BFS level map from the highest-out-degree vertex
deg = {}
for a, _ in E:
    deg[a] = deg.get(a, 0) + 1
hub = max(deg, key=deg.get)
levels = g.bfs(hub)
assert levels == oracle.bfs(hub)
by_depth = {}
for _, d in levels.items():
    by_depth[d] = by_depth.get(d, 0) + 1
print(f"bfs from hub {hub}: reaches {len(levels)} vertices, "
      f"frontier sizes {[by_depth[d] for d in sorted(by_depth)]}")

# bounded-depth neighborhood
for k in (1, 2, 3):
    nb = g.khop(hub, k)
    assert nb == oracle.khop(hub, k)
    print(f"  ≤{k} hops: {len(nb)} vertices")

# deletion + incarnation churn: paths through a removed vertex disappear,
# and re-adding the vertex must NOT resurrect its old edges (Fig. 3 hazard)
victim = next(w for w, d in levels.items() if d == 1)  # a direct neighbor
g.remove_vertex(victim); oracle.remove_vertex(victim)
g.add_vertex(victim); oracle.add_vertex(victim)
assert g.bfs(hub) == oracle.bfs(hub)
assert not g.reachable(hub, victim)
print(f"after remove+re-add of {victim}: hub reaches "
      f"{len(g.bfs(hub))} vertices (stale edges carry no path)")
print("all traversal answers match the sequential oracle")
