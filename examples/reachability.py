"""Batched wait-free reachability + snapshot + GetPath queries, end to end.

    PYTHONPATH=src python examples/reachability.py

Builds a graph under the ``traversal`` mix, then answers reachability, BFS
level, k-hop neighborhood, and explicit shortest-path (``GetPath``) queries
— every query batch runs against one consistent CSR snapshot of the
post-batch state (linearized at the batch boundary, like the wait-free
GetPath/snapshot of arXiv 1809.00896 and 2310.02380), and every answer is
cross-checked against the sequential oracle.  Update batches between
queries are folded into the cached snapshot incrementally
(``csr_maintenance="delta"``, the default) instead of forcing a rebuild.
"""

import numpy as np

from repro.core import SequentialGraph, WaitFreeGraph, build_csr, run_sequential
from repro.core.workloads import (
    initial_vertices,
    sample_batch,
    sample_query_pairs,
    sample_update_batch,
)

KEY_SPACE = 64
rng = np.random.default_rng(7)

# maintenance_impl="device" demos the compaction pipeline everywhere (the
# auto default picks it only on TPU; on CPU the host oracle is faster)
g = WaitFreeGraph(v_capacity=256, e_capacity=1024, mode="fpsp",
                  maintenance_impl="device")
oracle = SequentialGraph()
ops, us, vs = initial_vertices(KEY_SPACE)  # the paper's pre-seeded vertices
got = g.apply(ops, us, vs)
exp, oracle = run_sequential(ops, us, vs, graph=oracle)
assert got.tolist() == exp
for _ in range(3):
    ops, us, vs = sample_batch(rng, 128, "traversal", key_space=KEY_SPACE)
    got = g.apply(ops, us, vs)
    exp, oracle = run_sequential(ops, us, vs, graph=oracle)
    assert got.tolist() == exp

V, E = g.snapshot()
assert (V, E) == (oracle.vertices, oracle.edges)
print(f"graph: {len(V)} vertices, {len(E)} edges (consistent snapshot)")

# one batch of pairwise reachability queries, one shared snapshot
us, vs = sample_query_pairs(rng, 16, KEY_SPACE)
got = g.reachable(us, vs)
for u, v, r in zip(us, vs, got):
    assert bool(r) == oracle.reachable(int(u), int(v))
print(f"reachable: {int(got.sum())}/{len(got)} of a {len(got)}-pair batch connected")

# full BFS level map from the highest-out-degree vertex
deg = {}
for a, _ in E:
    deg[a] = deg.get(a, 0) + 1
hub = max(deg, key=deg.get)
levels = g.bfs(hub)
assert levels == oracle.bfs(hub)
by_depth = {}
for _, d in levels.items():
    by_depth[d] = by_depth.get(d, 0) + 1
print(f"bfs from hub {hub}: reaches {len(levels)} vertices, "
      f"frontier sizes {[by_depth[d] for d in sorted(by_depth)]}")

# bounded-depth neighborhood
for k in (1, 2, 3):
    nb = g.khop(hub, k)
    assert nb == oracle.khop(hub, k)
    print(f"  ≤{k} hops: {len(nb)} vertices")

# explicit shortest paths (the papers' GetPath): valid + length-optimal
far = max(levels, key=levels.get)
path = g.get_path(hub, far)
exp = oracle.path(hub, far)
assert path is not None and len(path) == len(exp)
for a, b in zip(path, path[1:]):
    assert (a, b) in oracle.edges
print(f"get_path {hub} -> {far}: {path} ({len(path) - 1} hops, oracle-shortest)")

# incremental snapshot maintenance: small update batches fold into the
# cached CSR (bit-identical to a rebuild) instead of discarding it
ops, us, vs = sample_update_batch(rng, 8, KEY_SPACE)
got = g.apply(ops, us, vs)
exp_res, oracle = run_sequential(ops, us, vs, graph=oracle)
assert got.tolist() == exp_res
delta_csr = g.traversal_csr()          # maintained by apply_delta inside apply
full_csr = build_csr(g.state)          # ground-truth rebuild
assert all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(delta_csr, full_csr)
)
print(f"delta-maintained snapshot == full rebuild "
      f"({int(full_csr.n_edges)} edges) after an 8-op update batch")

# deletion + incarnation churn: paths through a removed vertex disappear,
# and re-adding the vertex must NOT resurrect its old edges (Fig. 3 hazard)
levels = g.bfs(hub)
victim = next(w for w, d in levels.items() if d == 1)  # a direct neighbor
g.remove_vertex(victim)
oracle.remove_vertex(victim)
g.add_vertex(victim)
oracle.add_vertex(victim)
assert g.bfs(hub) == oracle.bfs(hub)
assert not g.reachable(hub, victim)
assert g.get_path(hub, victim) is None
print(f"after remove+re-add of {victim}: hub reaches "
      f"{len(g.bfs(hub))} vertices (stale edges carry no path)")

# device-side state maintenance: the update folds above already ran through
# the device delta-merge (this graph was built with an explicit
# maintenance_impl="device" — the auto default picks it only on TPU); now
# force a growth wave and let the rehash's snapshot-compact pre-seed the
# next query
pre_caps = (g.state.v_capacity, g.state.e_capacity)
ops, us, vs = initial_vertices(4 * KEY_SPACE)  # overflows the tables
got = g.apply(ops, us, vs)
exp_res, oracle = run_sequential(ops, us, vs, graph=oracle)
assert got.tolist() == exp_res
assert (g.state.v_capacity, g.state.e_capacity) != pre_caps
assert g.snapshot() == (oracle.vertices, oracle.edges)
grown_csr = g.traversal_csr()  # one delta fold off the rehash's own CSR
assert all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(grown_csr, build_csr(g.state))
)
print(f"growth {pre_caps} -> {(g.state.v_capacity, g.state.e_capacity)}: "
      f"device rehash + snapshot-compact, post-growth snapshot exact")

# hash-prefix sharding (repro.core.sharding): the same op stream through a
# 4-shard graph — BOTH tables partitioned by the prefix of their probe
# hashes (each shard stores only owned rows, O(N/S) + O(M/S)); the batch is
# routed as disjoint sub-batches and a cross-shard stabbing wave carries
# endpoint liveness to edge ops — yet every query answers identically to
# the 1-shard graph, against one fused (directory-placed) CSR snapshot
from repro.core.workloads import shard_balance

rng = np.random.default_rng(13)
g1 = WaitFreeGraph(v_capacity=256, e_capacity=1024, mode="fpsp")
g4 = WaitFreeGraph(v_capacity=256, e_capacity=1024, mode="fpsp", n_shards=4)
stream = [initial_vertices(KEY_SPACE)] + [
    sample_batch(rng, 128, "traversal", key_space=KEY_SPACE) for _ in range(3)
]
loads = np.zeros(4, np.int64)
for ops, us, vs in stream:
    res1 = g1.apply(ops, us, vs)  # mutations outside asserts: -O safe
    res4 = g4.apply(ops, us, vs)
    assert res1.tolist() == res4.tolist()
    loads += shard_balance(ops, us, vs, 4)
assert g4.snapshot() == g1.snapshot()
us, vs = sample_query_pairs(rng, 16, KEY_SPACE)
assert np.array_equal(g4.reachable(us, vs), g1.reachable(us, vs))
assert g4.bfs(hub) == g1.bfs(hub)
assert g4.get_path_batch(us[:4], vs[:4]) == g1.get_path_batch(us[:4], vs[:4])
print(f"4-shard graph: edge-op load per shard {loads.tolist()} "
      f"(hash-prefix balance), per-shard e_caps "
      f"{[s.e_capacity for s in g4.shards]}, all answers == 1-shard graph")

# wait-free telemetry (repro.obs, docs/OBSERVABILITY.md): replay the same
# stream through an instrumented 2-shard graph — every metric is derived
# from arrays the jitted programs already compute, so obs on/off is
# byte-identical (tests/test_obs.py pins it); the registry collects the
# FPSP fast/slow lane split, claim-round histograms (the helping-bound
# witness), per-phase spans of the sharded pipeline, and probe-chain
# health over the final tables
from repro.obs import fastpath_frac

gobs = WaitFreeGraph(v_capacity=256, e_capacity=1024, mode="fpsp",
                     n_shards=2, obs=True)
for ops, us_b, vs_b in stream:
    gobs.apply(ops, us_b, vs_b)
assert np.array_equal(gobs.reachable(us, vs), g1.reachable(us, vs))
probe = gobs.probe_health()
dump = gobs.obs.dump()
rounds = gobs.obs.hist_counts("engine.claim_rounds")
print(f"telemetry: fastpath_frac={fastpath_frac(gobs.obs):.3f}, "
      f"claim rounds {rounds} (p99={gobs.obs.percentile('engine.claim_rounds', 99):.0f}), "
      f"vertex probe hist {probe['vertex']}")
print(f"telemetry: phases timed: "
      f"{[k for k in dump['spans'] if k.startswith('phase.')]}"
      f" -> render any dump with tools/obs_report.py")
print("all traversal answers match the sequential oracle")
