"""End-to-end LM training on CPU: real data pipeline, sharded step,
async checkpoints, kill-safe resume.

    PYTHONPATH=src python examples/train_lm.py                # ~25M params
    PYTHONPATH=src python examples/train_lm.py --params-100m  # ~100M params

Loss should fall from ~ln(vocab) toward the Zipf+motif entropy floor within
a few hundred steps.  Re-running the same command resumes from the latest
checkpoint (delete --ckpt-dir to restart).
"""

import argparse

from repro.launch.train import TrainRunner, make_mesh
from repro.models.config import ArchConfig


def nano_config(big: bool) -> ArchConfig:
    if big:  # ~100M params
        return ArchConfig(
            name="nano-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=16384, dtype="float32",
        )
    return ArchConfig(  # ~25M params
        name="nano-25m", family="dense", n_layers=8, d_model=384,
        n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192, dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = nano_config(args.params_100m)
    runner = TrainRunner(
        cfg, make_mesh("1x1"), ckpt_dir=args.ckpt_dir,
        batch=args.batch, seq=args.seq,
    )
    print(f"[{cfg.name}] {runner.init_or_restore()} @ step {runner.step}")
    losses = runner.train(args.steps, log_every=10, save_every=100)
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT — check setup'})")


if __name__ == "__main__":
    main()
